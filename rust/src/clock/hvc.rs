//! Hybrid Vector Clocks (HVC, Demirbas & Kulkarni) and the paper's
//! HVC-*interval* causality rule used by the monitors (§V, Fig. 6).
//!
//! An HVC at process `i` is a vector of the most recent *physical* times
//! process `i` knows about every process, with `hvc[i] = PT_i`. Entries are
//! floored at `PT_i - ε` (ε = clock synchronization error bound), which is
//! what allows compression when ε is finite; with ε = ∞ an HVC behaves as a
//! plain vector clock over physical timestamps (the setting the paper uses
//! in its experiments).
//!
//! Clock values are milliseconds (`i64`); the monitors and the AOT kernels
//! operate at this granularity. Coarsening only errs toward "concurrent",
//! the paper's safe direction (no missed violations).
//!
//! ## Hot-path representation
//!
//! The vector itself is an [`HvcVec`] — a hand-rolled small-vector with
//! inline capacity for [`HVC_INLINE_CAP`] servers, spilling to the heap
//! only for larger clusters (the scale-out S=24 scenarios). At the
//! paper's deployment sizes (N = 3/5) a clock clone is a stack copy, no
//! allocation. On top of that, [`HvcInterval`] endpoints are `Rc<Hvc>`
//! snapshots: the server's clock is shared into messages and candidate
//! intervals by reference count, and mutated copy-on-write
//! (`Rc::make_mut`) at the next tick — see `store/server.rs`. Both are
//! pure representation changes: every comparison is by value, so same
//! seed ⇒ the same event schedule (pinned by
//! `store_integration::clock_representation_is_observationally_pure`).

use std::cmp::Ordering;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};

/// Physical time in milliseconds.
pub type Millis = i64;

/// Sentinel for "ε = ∞" (pure vector-clock behaviour).
pub const EPS_INF: Millis = i64::MAX / 4;

/// Inline capacity of [`HvcVec`]: clock vectors of up to this many
/// servers live on the stack; larger clusters spill to the heap.
pub const HVC_INLINE_CAP: usize = 8;

/// Test/bench hook: force every newly built [`HvcVec`] onto the heap —
/// the pre-optimization `Vec<Millis>` representation. The purity
/// regression runs the same seed inline vs spilled and pins identical
/// schedules; the micro bench uses it to time the representations
/// side by side. Mixed representations are safe (all comparisons are by
/// value), so flipping this mid-run only changes where bytes live.
pub fn set_force_spill(on: bool) {
    FORCE_SPILL.store(on, AtomicOrdering::Relaxed);
}

static FORCE_SPILL: AtomicBool = AtomicBool::new(false);

#[inline]
fn spills(n: usize) -> bool {
    n > HVC_INLINE_CAP || FORCE_SPILL.load(AtomicOrdering::Relaxed)
}

/// A hand-rolled small-vector of clock entries: inline storage for
/// dimensions up to [`HVC_INLINE_CAP`], heap spill above (no external
/// small-vector dependency — offline builds). Equality and hashing are
/// by *value*, never by representation, so an inline and a spilled
/// vector holding the same entries are indistinguishable.
#[derive(Debug, Clone)]
pub enum HvcVec {
    Inline { len: u8, buf: [Millis; HVC_INLINE_CAP] },
    Heap(Vec<Millis>),
}

impl HvcVec {
    pub fn new() -> Self {
        if spills(0) {
            HvcVec::Heap(Vec::new())
        } else {
            HvcVec::Inline { len: 0, buf: [0; HVC_INLINE_CAP] }
        }
    }

    /// `n` copies of `x` (the floor-fill constructor of [`Hvc::new`]).
    pub fn from_elem(x: Millis, n: usize) -> Self {
        if spills(n) {
            HvcVec::Heap(vec![x; n])
        } else {
            let mut buf = [0; HVC_INLINE_CAP];
            buf[..n].fill(x);
            HvcVec::Inline { len: n as u8, buf }
        }
    }

    pub fn from_vec(v: Vec<Millis>) -> Self {
        if spills(v.len()) {
            HvcVec::Heap(v)
        } else {
            let mut buf = [0; HVC_INLINE_CAP];
            buf[..v.len()].copy_from_slice(&v);
            HvcVec::Inline { len: v.len() as u8, buf }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            HvcVec::Inline { len, .. } => *len as usize,
            HvcVec::Heap(v) => v.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is this vector heap-spilled (dim > [`HVC_INLINE_CAP`] or forced)?
    pub fn spilled(&self) -> bool {
        matches!(self, HvcVec::Heap(_))
    }

    #[inline]
    pub fn as_slice(&self) -> &[Millis] {
        match self {
            HvcVec::Inline { len, buf } => &buf[..*len as usize],
            HvcVec::Heap(v) => v,
        }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [Millis] {
        match self {
            HvcVec::Inline { len, buf } => &mut buf[..*len as usize],
            HvcVec::Heap(v) => v,
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> Option<&Millis> {
        self.as_slice().get(i)
    }

    #[inline]
    pub fn iter(&self) -> std::slice::Iter<'_, Millis> {
        self.as_slice().iter()
    }

    #[inline]
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, Millis> {
        self.as_mut_slice().iter_mut()
    }

    pub fn push(&mut self, x: Millis) {
        match self {
            HvcVec::Inline { len, buf } => {
                let n = *len as usize;
                if n < HVC_INLINE_CAP {
                    buf[n] = x;
                    *len += 1;
                } else {
                    let mut v = buf.to_vec();
                    v.push(x);
                    *self = HvcVec::Heap(v);
                }
            }
            HvcVec::Heap(v) => v.push(x),
        }
    }
}

impl Default for HvcVec {
    fn default() -> Self {
        Self::new()
    }
}

impl From<Vec<Millis>> for HvcVec {
    fn from(v: Vec<Millis>) -> Self {
        Self::from_vec(v)
    }
}

impl FromIterator<Millis> for HvcVec {
    fn from_iter<I: IntoIterator<Item = Millis>>(it: I) -> Self {
        let mut out = HvcVec::new();
        for x in it {
            out.push(x);
        }
        out
    }
}

impl std::ops::Index<usize> for HvcVec {
    type Output = Millis;
    #[inline]
    fn index(&self, i: usize) -> &Millis {
        &self.as_slice()[i]
    }
}

impl std::ops::IndexMut<usize> for HvcVec {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut Millis {
        &mut self.as_mut_slice()[i]
    }
}

impl<'a> IntoIterator for &'a HvcVec {
    type Item = &'a Millis;
    type IntoIter = std::slice::Iter<'a, Millis>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<'a> IntoIterator for &'a mut HvcVec {
    type Item = &'a mut Millis;
    type IntoIter = std::slice::IterMut<'a, Millis>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter_mut()
    }
}

impl PartialEq for HvcVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for HvcVec {}

impl std::hash::Hash for HvcVec {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.as_slice().hash(state);
    }
}

/// Comparison result for HVC vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HvcOrd {
    Equal,
    Before,
    After,
    Concurrent,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Hvc {
    /// owning process index (a server id in this system)
    pub owner: u16,
    /// dense vector, one entry per process, in ms
    pub v: HvcVec,
}

impl Hvc {
    /// A fresh clock for process `owner` among `n` processes at time `pt`,
    /// with all remote entries at the `pt - eps` floor.
    pub fn new(owner: u16, n: usize, pt: Millis, eps: Millis) -> Self {
        let floor = pt.saturating_sub(eps);
        let mut v = HvcVec::from_elem(floor, n);
        v[owner as usize] = pt;
        Self { owner, v }
    }

    /// A clock over an explicit entry vector (tests/benches).
    pub fn from_vec(owner: u16, v: Vec<Millis>) -> Self {
        Self { owner, v: HvcVec::from_vec(v) }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Advance on a local event / message *send* at physical time `pt`:
    /// `v[i] = pt`, `v[j] = max(v[j], pt - eps)`. The own entry reduces
    /// to a plain `max` — it stays monotone even if the OS clock stalls.
    pub fn tick(&mut self, pt: Millis, eps: Millis) {
        let floor = pt.saturating_sub(eps);
        for x in &mut self.v {
            if *x < floor {
                *x = floor;
            }
        }
        let i = self.owner as usize;
        self.v[i] = self.v[i].max(pt);
    }

    /// Merge a piggy-backed clock on message *receive* at physical time
    /// `pt`: `v[i] = pt`, `v[j] = max(msg[j], v[j], pt - eps)`.
    pub fn recv(&mut self, msg: &Hvc, pt: Millis, eps: Millis) {
        debug_assert_eq!(self.dim(), msg.dim());
        let floor = pt.saturating_sub(eps);
        for (x, &m) in self.v.iter_mut().zip(msg.v.iter()) {
            *x = (*x).max(m).max(floor);
        }
        let i = self.owner as usize;
        self.v[i] = self.v[i].max(pt);
    }

    /// Standard vector comparison.
    pub fn compare(&self, other: &Hvc) -> HvcOrd {
        debug_assert_eq!(self.dim(), other.dim());
        let mut less = false;
        let mut greater = false;
        for (a, b) in self.v.iter().zip(other.v.iter()) {
            match a.cmp(b) {
                Ordering::Less => less = true,
                Ordering::Greater => greater = true,
                Ordering::Equal => {}
            }
            if less && greater {
                return HvcOrd::Concurrent;
            }
        }
        match (less, greater) {
            (false, false) => HvcOrd::Equal,
            (true, false) => HvcOrd::Before,
            (false, true) => HvcOrd::After,
            (true, true) => HvcOrd::Concurrent,
        }
    }

    #[inline]
    pub fn strictly_before(&self, other: &Hvc) -> bool {
        self.compare(other) == HvcOrd::Before
    }

    /// Number of entries that differ from the `pt - eps` floor — the
    /// compressed representation size the paper describes (a bitmap of n
    /// bits plus this many explicit integers).
    pub fn compressed_len(&self, eps: Millis) -> usize {
        let pt = self.v[self.owner as usize];
        let floor = pt.saturating_sub(eps);
        self.v.iter().filter(|&&x| x != floor).count()
    }

    /// Compress to (bitmap, explicit values); inverse of [`Hvc::decompress`].
    pub fn compress(&self, eps: Millis) -> (Vec<bool>, Vec<Millis>) {
        let pt = self.v[self.owner as usize];
        let floor = pt.saturating_sub(eps);
        let bitmap: Vec<bool> = self.v.iter().map(|&x| x != floor).collect();
        let vals: Vec<Millis> = self.v.iter().copied().filter(|&x| x != floor).collect();
        (bitmap, vals)
    }

    pub fn decompress(owner: u16, bitmap: &[bool], vals: &[Millis], pt: Millis, eps: Millis) -> Self {
        let floor = pt.saturating_sub(eps);
        let mut vi = vals.iter();
        let v = bitmap
            .iter()
            .map(|&set| if set { *vi.next().expect("bitmap/vals mismatch") } else { floor })
            .collect();
        Self { owner, v }
    }
}

/// An HVC interval `[start, end]` on a server — the time span attached to a
/// candidate sent to a monitor (the local predicate held throughout it).
///
/// Endpoints are `Rc<Hvc>` snapshots shared with the emitting server's
/// clock history (copy-on-tick keeps them immutable); cloning a candidate
/// or building a point interval `[now, now]` bumps reference counts
/// instead of copying clock vectors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HvcInterval {
    pub start: Rc<Hvc>,
    pub end: Rc<Hvc>,
}

/// Verdict of the paper's 3-case interval causality rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntervalOrd {
    /// overlapping or within the ε-uncertainty window → treated concurrent
    Concurrent,
    /// first interval happened before the second
    Before,
    /// second interval happened before the first
    After,
}

impl HvcInterval {
    pub fn new(start: impl Into<Rc<Hvc>>, end: impl Into<Rc<Hvc>>) -> Self {
        let (start, end) = (start.into(), end.into());
        debug_assert_eq!(start.owner, end.owner);
        Self { start, end }
    }

    pub fn owner(&self) -> u16 {
        self.start.owner
    }

    /// The paper's rule (§V "Implementation of the monitors", Fig. 6),
    /// applied after orienting so that ¬(start_a > start_b):
    ///
    /// 1. if ¬(end_a < start_b)          → Concurrent (common segment);
    /// 2. if end_a < start_b and
    ///    end_a[Sa] ≤ start_b[Sb] − ε    → `a` Before `b`;
    /// 3. if end_a < start_b but the physical separation is within ε
    ///                                   → Concurrent (uncertain, safe).
    pub fn verdict(a: &HvcInterval, b: &HvcInterval, eps: Millis) -> IntervalOrd {
        // orient: ensure ¬(start_a > start_b)
        if a.start.compare(&b.start) == HvcOrd::After {
            return match Self::verdict(b, a, eps) {
                IntervalOrd::Before => IntervalOrd::After,
                IntervalOrd::After => IntervalOrd::Before,
                IntervalOrd::Concurrent => IntervalOrd::Concurrent,
            };
        }
        if a.end.strictly_before(&b.start) {
            let pa = a.end.v[a.owner() as usize];
            let pb = b.start.v[b.owner() as usize];
            if pa <= pb.saturating_sub(eps) {
                IntervalOrd::Before
            } else {
                IntervalOrd::Concurrent
            }
        } else {
            // overlap (including vector-concurrent endpoints): common segment
            IntervalOrd::Concurrent
        }
    }

    /// Convenience: are the two intervals to be treated as concurrent?
    pub fn concurrent(a: &HvcInterval, b: &HvcInterval, eps: Millis) -> bool {
        Self::verdict(a, b, eps) == IntervalOrd::Concurrent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Tests that toggle or assert the process-global spill flag must
    /// hold this lock — cargo's parallel test threads would otherwise
    /// race a toggling test against a representation assertion.
    static SPILL_FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn hvc(owner: u16, v: &[Millis]) -> Hvc {
        Hvc::from_vec(owner, v.to_vec())
    }

    #[test]
    fn paper_compression_example() {
        // n=10, eps=20, HVC_0 = [100,80,80,95,80,80,100,80,80,80]
        // → 3 explicit integers (100, 95, 100)
        let h = hvc(0, &[100, 80, 80, 95, 80, 80, 100, 80, 80, 80]);
        assert_eq!(h.compressed_len(20), 3);
        let (bitmap, vals) = h.compress(20);
        assert_eq!(vals, vec![100, 95, 100]);
        let back = Hvc::decompress(0, &bitmap, &vals, 100, 20);
        assert_eq!(back, h);
    }

    #[test]
    fn tick_and_recv_monotone() {
        let eps = 10;
        let mut a = Hvc::new(0, 3, 100, eps);
        a.tick(105, eps);
        assert_eq!(a.v[0], 105);
        assert_eq!(a.v[1], 95);
        let b = Hvc::new(1, 3, 104, eps);
        let before = a.clone();
        a.recv(&b, 106, eps);
        assert_eq!(a.v[0], 106);
        assert_eq!(a.v[1], 104); // learned from b
        assert!(matches!(before.compare(&a), HvcOrd::Before));
    }

    #[test]
    fn tick_own_entry_monotone_through_clock_stall() {
        // the OS clock standing still (or stepping back) must not move
        // the own entry backwards — the old two-arm branch and the `max`
        // it folded into agree on this
        let mut a = Hvc::new(0, 2, 100, 10);
        a.tick(90, 10);
        assert_eq!(a.v[0], 100, "own entry never regresses");
        a.tick(100, 10);
        assert_eq!(a.v[0], 100);
        a.tick(101, 10);
        assert_eq!(a.v[0], 101);
    }

    #[test]
    fn inline_and_spilled_representations_are_equal() {
        let _guard = SPILL_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dims = [1usize, 2, 7, 8, 9, 16];
        for &n in &dims {
            let inline = Hvc::new(0, n, 500, 20);
            set_force_spill(true);
            let spilled = Hvc::new(0, n, 500, 20);
            set_force_spill(false);
            assert_eq!(inline, spilled, "value equality across representations (n={n})");
            assert_eq!(inline.compare(&spilled), HvcOrd::Equal);
            if n > HVC_INLINE_CAP {
                assert!(inline.v.spilled(), "dim {n} must spill");
            } else {
                assert!(!inline.v.spilled(), "dim {n} stays inline");
                assert!(spilled.v.spilled(), "force hook spills dim {n}");
            }
        }
    }

    #[test]
    fn hvcvec_push_spills_past_inline_cap() {
        let _guard = SPILL_FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut v = HvcVec::new();
        for i in 0..HVC_INLINE_CAP as i64 {
            v.push(i);
        }
        assert!(!v.spilled());
        v.push(99);
        assert!(v.spilled());
        assert_eq!(v.len(), HVC_INLINE_CAP + 1);
        let expect: Vec<Millis> = (0..HVC_INLINE_CAP as i64).chain([99]).collect();
        assert_eq!(v.as_slice(), &expect[..]);
        // hashing is representation-independent too
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let h = |x: &HvcVec| {
            let mut s = DefaultHasher::new();
            x.hash(&mut s);
            s.finish()
        };
        let w = HvcVec::from_vec(expect);
        assert_eq!(h(&v), h(&w));
    }

    #[test]
    fn compare_cases() {
        let a = hvc(0, &[5, 5]);
        let b = hvc(0, &[6, 6]);
        let c = hvc(1, &[6, 4]);
        assert_eq!(a.compare(&b), HvcOrd::Before);
        assert_eq!(b.compare(&a), HvcOrd::After);
        assert_eq!(a.compare(&a), HvcOrd::Equal);
        assert_eq!(a.compare(&c), HvcOrd::Concurrent);
    }

    #[test]
    fn interval_rule_overlap() {
        // intervals share a segment → concurrent regardless of eps
        let i1 = HvcInterval::new(hvc(0, &[10, 0]), hvc(0, &[20, 0]));
        let i2 = HvcInterval::new(hvc(1, &[15, 15]), hvc(1, &[15, 25]));
        assert_eq!(HvcInterval::verdict(&i1, &i2, 0), IntervalOrd::Concurrent);
    }

    #[test]
    fn interval_rule_clear_precedence() {
        // end1 < start2 vector-wise AND physically separated by > eps
        let i1 = HvcInterval::new(hvc(0, &[10, 5]), hvc(0, &[20, 5]));
        let i2 = HvcInterval::new(hvc(1, &[25, 40]), hvc(1, &[25, 50]));
        assert_eq!(HvcInterval::verdict(&i1, &i2, 5), IntervalOrd::Before);
        assert_eq!(HvcInterval::verdict(&i2, &i1, 5), IntervalOrd::After);
    }

    #[test]
    fn interval_rule_uncertain_window() {
        // end1 < start2 vector-wise, but physical separation within eps →
        // uncertain → concurrent (the "avoid missing possible bugs" case)
        let i1 = HvcInterval::new(hvc(0, &[10, 5]), hvc(0, &[20, 5]));
        let i2 = HvcInterval::new(hvc(1, &[25, 22]), hvc(1, &[25, 50]));
        // separation = start2[1] - end1[0] = 22 - 20 = 2 < eps=5
        assert_eq!(HvcInterval::verdict(&i1, &i2, 5), IntervalOrd::Concurrent);
        // with eps=1 it's a clear precedence (20 <= 22 - 1)
        assert_eq!(HvcInterval::verdict(&i1, &i2, 1), IntervalOrd::Before);
    }

    fn random_hvc(rng: &mut Rng, owner: u16, n: usize) -> Hvc {
        let base = rng.range(0, 1000) as i64;
        let v = (0..n).map(|_| base + rng.range(0, 50) as i64).collect();
        Hvc::from_vec(owner, v)
    }

    fn random_interval(rng: &mut Rng, n: usize) -> HvcInterval {
        let owner = rng.below(n as u64) as u16;
        let s = random_hvc(rng, owner, n);
        let mut e = s.clone();
        for x in &mut e.v {
            *x += rng.range(0, 40) as i64;
        }
        e.v[owner as usize] += 1; // end strictly after start at owner
        HvcInterval::new(s, e)
    }

    #[test]
    fn prop_hvc_compare_antisymmetric() {
        prop::check_default("hvc_antisymmetric", |rng| {
            let n = rng.range(2, 6) as usize;
            let a = random_hvc(rng, 0, n);
            let b = random_hvc(rng, 1, n);
            let ok = matches!(
                (a.compare(&b), b.compare(&a)),
                (HvcOrd::Equal, HvcOrd::Equal)
                    | (HvcOrd::Before, HvcOrd::After)
                    | (HvcOrd::After, HvcOrd::Before)
                    | (HvcOrd::Concurrent, HvcOrd::Concurrent)
            );
            if ok {
                Ok(())
            } else {
                Err(format!("a={a:?} b={b:?}"))
            }
        });
    }

    #[test]
    fn prop_interval_verdict_antisymmetric() {
        prop::check_default("interval_antisymmetric", |rng| {
            let n = rng.range(2, 6) as usize;
            let a = random_interval(rng, n);
            let b = random_interval(rng, n);
            let eps = rng.range(0, 30) as i64;
            let ok = matches!(
                (HvcInterval::verdict(&a, &b, eps), HvcInterval::verdict(&b, &a, eps)),
                (IntervalOrd::Concurrent, IntervalOrd::Concurrent)
                    | (IntervalOrd::Before, IntervalOrd::After)
                    | (IntervalOrd::After, IntervalOrd::Before)
            );
            if ok {
                Ok(())
            } else {
                Err(format!("a={a:?} b={b:?} eps={eps}"))
            }
        });
    }

    #[test]
    fn prop_larger_eps_never_unconcurrents() {
        // Growing ε only moves verdicts toward Concurrent (safety): if two
        // intervals are concurrent at ε they stay concurrent at ε' > ε.
        prop::check_default("eps_monotone_safety", |rng| {
            let n = rng.range(2, 5) as usize;
            let a = random_interval(rng, n);
            let b = random_interval(rng, n);
            let e1 = rng.range(0, 20) as i64;
            let e2 = e1 + rng.range(1, 20) as i64;
            let v1 = HvcInterval::verdict(&a, &b, e1);
            let v2 = HvcInterval::verdict(&a, &b, e2);
            if v1 == IntervalOrd::Concurrent && v2 != IntervalOrd::Concurrent {
                return Err(format!("eps {e1}->{e2} un-concurrented: {a:?} {b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_compress_roundtrip() {
        prop::check_default("hvc_compress_roundtrip", |rng| {
            let n = rng.range(2, 12) as usize;
            let owner = rng.below(n as u64) as u16;
            let eps = rng.range(1, 50) as i64;
            let pt = rng.range(100, 10_000) as i64;
            let mut h = Hvc::new(owner, n, pt, eps);
            // randomly raise some entries above the floor
            for j in 0..n {
                if rng.chance(0.4) {
                    h.v[j] = pt - rng.range(0, eps as u64) as i64;
                }
            }
            h.v[owner as usize] = pt;
            let (bm, vals) = h.compress(eps);
            let back = Hvc::decompress(owner, &bm, &vals, pt, eps);
            if back != h {
                return Err(format!("roundtrip mismatch {h:?} -> {back:?}"));
            }
            Ok(())
        });
    }
}
