//! Vector clocks for value *versions* (the Voldemort role: each stored
//! value carries a vector clock over the writing clients; concurrent
//! writes produce sibling versions).

use std::cmp::Ordering;

/// Sparse vector clock: sorted `(node_id, counter)` pairs.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct VectorClock {
    entries: Vec<(u32, u64)>,
}

/// Result of comparing two vector clocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Causality {
    Equal,
    /// self < other (self happened before other)
    Before,
    /// self > other
    After,
    Concurrent,
}

impl VectorClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, node: u32) -> u64 {
        self.entries
            .binary_search_by_key(&node, |e| e.0)
            .map(|i| self.entries[i].1)
            .unwrap_or(0)
    }

    /// Increment `node`'s component (a client stamping its write).
    pub fn increment(&mut self, node: u32) {
        match self.entries.binary_search_by_key(&node, |e| e.0) {
            Ok(i) => self.entries[i].1 += 1,
            Err(i) => self.entries.insert(i, (node, 1)),
        }
    }

    pub fn incremented(mut self, node: u32) -> Self {
        self.increment(node);
        self
    }

    /// Pointwise max, in place — the hot-path variant: the quorum
    /// engine folds every GET_VERSION reply into one accumulator
    /// without allocating a fresh clock per merge.
    pub fn merge_from(&mut self, other: &Self) {
        for &(node, cnt) in other.entries() {
            match self.entries.binary_search_by_key(&node, |e| e.0) {
                Ok(i) => {
                    if self.entries[i].1 < cnt {
                        self.entries[i].1 = cnt;
                    }
                }
                Err(i) => self.entries.insert(i, (node, cnt)),
            }
        }
    }

    /// Pointwise max.
    pub fn merge(&self, other: &Self) -> Self {
        let mut out = Vec::with_capacity(self.entries.len().max(other.entries.len()));
        let (mut i, mut j) = (0, 0);
        while i < self.entries.len() || j < other.entries.len() {
            match (self.entries.get(i), other.entries.get(j)) {
                (Some(&(a, av)), Some(&(b, bv))) => {
                    if a == b {
                        out.push((a, av.max(bv)));
                        i += 1;
                        j += 1;
                    } else if a < b {
                        out.push((a, av));
                        i += 1;
                    } else {
                        out.push((b, bv));
                        j += 1;
                    }
                }
                (Some(&e), None) => {
                    out.push(e);
                    i += 1;
                }
                (None, Some(&e)) => {
                    out.push(e);
                    j += 1;
                }
                (None, None) => unreachable!(),
            }
        }
        Self { entries: out }
    }

    /// Compare for causality.
    pub fn compare(&self, other: &Self) -> Causality {
        let mut less = false; // some component self < other
        let mut greater = false;
        let (mut i, mut j) = (0, 0);
        while i < self.entries.len() || j < other.entries.len() {
            match (self.entries.get(i), other.entries.get(j)) {
                (Some(&(a, av)), Some(&(b, bv))) => {
                    if a == b {
                        match av.cmp(&bv) {
                            Ordering::Less => less = true,
                            Ordering::Greater => greater = true,
                            Ordering::Equal => {}
                        }
                        i += 1;
                        j += 1;
                    } else if a < b {
                        // other has implicit 0 here
                        greater = true;
                        i += 1;
                    } else {
                        less = true;
                        j += 1;
                    }
                }
                (Some(_), None) => {
                    greater = true;
                    i += 1;
                }
                (None, Some(_)) => {
                    less = true;
                    j += 1;
                }
                (None, None) => unreachable!(),
            }
            if less && greater {
                return Causality::Concurrent;
            }
        }
        match (less, greater) {
            (false, false) => Causality::Equal,
            (true, false) => Causality::Before,
            (false, true) => Causality::After,
            (true, true) => Causality::Concurrent,
        }
    }

    pub fn dominates(&self, other: &Self) -> bool {
        matches!(self.compare(other), Causality::After | Causality::Equal)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[(u32, u64)] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn basic_ordering() {
        let a = VectorClock::new().incremented(1); // {1:1}
        let b = a.clone().incremented(1); // {1:2}
        assert_eq!(a.compare(&b), Causality::Before);
        assert_eq!(b.compare(&a), Causality::After);
        assert_eq!(a.compare(&a), Causality::Equal);
    }

    #[test]
    fn concurrent_writes() {
        let base = VectorClock::new();
        let a = base.clone().incremented(1);
        let b = base.incremented(2);
        assert_eq!(a.compare(&b), Causality::Concurrent);
        assert_eq!(b.compare(&a), Causality::Concurrent);
    }

    #[test]
    fn merge_dominates_both() {
        let a = VectorClock::new().incremented(1).incremented(1);
        let b = VectorClock::new().incremented(2);
        let m = a.merge(&b);
        assert!(m.dominates(&a));
        assert!(m.dominates(&b));
        assert_eq!(m.get(1), 2);
        assert_eq!(m.get(2), 1);
    }

    #[test]
    fn implicit_zero_entries() {
        let a = VectorClock::new().incremented(5);
        let empty = VectorClock::new();
        assert_eq!(empty.compare(&a), Causality::Before);
        assert_eq!(a.compare(&empty), Causality::After);
    }

    fn random_vc(rng: &mut crate::util::rng::Rng) -> VectorClock {
        let mut vc = VectorClock::new();
        let n = rng.below(5);
        for _ in 0..n {
            let node = rng.below(6) as u32;
            let times = rng.range(1, 4);
            for _ in 0..times {
                vc.increment(node);
            }
        }
        vc
    }

    #[test]
    fn prop_compare_antisymmetric() {
        prop::check_default("vc_antisymmetric", |rng| {
            let a = random_vc(rng);
            let b = random_vc(rng);
            let ab = a.compare(&b);
            let ba = b.compare(&a);
            let ok = matches!(
                (ab, ba),
                (Causality::Equal, Causality::Equal)
                    | (Causality::Before, Causality::After)
                    | (Causality::After, Causality::Before)
                    | (Causality::Concurrent, Causality::Concurrent)
            );
            if ok {
                Ok(())
            } else {
                Err(format!("a={a:?} b={b:?} ab={ab:?} ba={ba:?}"))
            }
        });
    }

    #[test]
    fn prop_merge_is_lub() {
        prop::check_default("vc_merge_lub", |rng| {
            let a = random_vc(rng);
            let b = random_vc(rng);
            let m = a.merge(&b);
            if !m.dominates(&a) || !m.dominates(&b) {
                return Err(format!("merge not upper bound: a={a:?} b={b:?} m={m:?}"));
            }
            // the in-place variant must agree exactly
            let mut m2 = a.clone();
            m2.merge_from(&b);
            if m2 != m {
                return Err(format!("merge_from disagrees: {m:?} vs {m2:?}"));
            }
            // least: every component equals max of inputs
            for &(n, v) in m.entries() {
                if v != a.get(n).max(b.get(n)) {
                    return Err(format!("component {n} not max"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_increment_strictly_after() {
        prop::check_default("vc_increment_after", |rng| {
            let a = random_vc(rng);
            let b = a.clone().incremented(rng.below(6) as u32);
            if b.compare(&a) != Causality::After {
                return Err(format!("increment not after: {a:?} -> {b:?}"));
            }
            Ok(())
        });
    }
}
