//! End-to-end tests of the fault-injection subsystem through the full
//! experiment runner: the `FaultPlan::none()` inertness regression, a
//! partition exercising quorum timeouts / optimistic progress /
//! detection / post-heal recovery, crash-restart with peer re-sync,
//! schedule determinism, and the §VI detection-latency CDF shape.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios;
use optikv::faults::{FaultEvent, FaultPlan};
use optikv::sim::SEC;

fn small_conj(consistency: ConsistencyCfg) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        "faults-e2e",
        consistency,
        AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
    );
    cfg.n_clients = 6;
    cfg.monitors = true;
    cfg.duration = 40 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg
}

fn fingerprint(r: &ExpResult) -> (u64, u64, usize, u64, f64) {
    (r.ops_ok, r.ops_failed, r.violations_detected, r.sim_stats.events, r.app_tps)
}

// ---------------------------------------------------------------------------
// regression: the empty plan (and a plan that never activates) is inert
// ---------------------------------------------------------------------------

#[test]
fn none_plan_reproduces_the_fault_free_run_event_for_event() {
    let base = run(&small_conj(ConsistencyCfg::n3r1w1()));
    let explicit_none =
        run(&small_conj(ConsistencyCfg::n3r1w1()).with_fault_plan(FaultPlan::none()));
    assert_eq!(fingerprint(&base), fingerprint(&explicit_none));

    // a plan whose first window opens after the run ends must be inert
    // too: installing the subsystem costs nothing until a fault fires
    let dormant = run(&small_conj(ConsistencyCfg::n3r1w1()).with_fault_plan(
        FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0], vec![1, 2]],
            from: 400 * SEC,
            until: 500 * SEC,
        }),
    ));
    assert_eq!(fingerprint(&base), fingerprint(&dormant), "dormant plan changed the run");
    assert_eq!(dormant.sim_stats.fault_dropped, 0);
    assert_eq!(dormant.sim_stats.fault_transitions, 0, "no window opened inside the run");
    assert_eq!(base.crashes, 0);
    assert_eq!(base.resyncs, 0);
}

// ---------------------------------------------------------------------------
// partition: timeouts, optimistic progress, detection, post-heal recovery
// ---------------------------------------------------------------------------

/// N3R1W2 under a partition isolating region 0 for [15 s, 25 s):
/// * clients in region 0 can reach only server 0 → W = 2 writes run the
///   serial round and fail → quorum timeouts;
/// * R = 1 reads and majority-side writes keep succeeding → optimistic
///   progress continues;
/// * replicas diverge across the cut → violations keep being detected;
/// * after the heal, failed ops stop and throughput returns.
fn partitioned_cfg() -> ExpConfig {
    small_conj(ConsistencyCfg::new(3, 1, 2)).with_fault_plan(FaultPlan::none().with(
        FaultEvent::Partition {
            groups: vec![vec![0], vec![1, 2]],
            from: 15 * SEC,
            until: 25 * SEC,
        },
    ))
}

#[test]
fn partition_shows_timeouts_progress_detection_and_heal() {
    let res = run(&partitioned_cfg());
    assert!(res.sim_stats.fault_transitions == 2, "cut + heal applied");
    assert!(res.sim_stats.fault_dropped > 0, "messages crossed the cut and were lost");
    assert!(res.ops_failed > 0, "isolated-region W=2 writes must time out");
    assert!(res.ops_ok > 100, "optimistic progress continues: {}", res.ops_ok);
    assert!(res.violations_detected > 0, "detection survives the partition");

    // post-heal recovery: the last windows of the run serve again at a
    // healthy clip (compare against the pre-cut stable mean)
    let series = res.metrics.borrow().app_series();
    assert!(series.len() > 30, "closed-loop clients ran past the heal: {}", series.len());
    let window_mean = |a: usize, b: usize| -> f64 {
        let (a, b) = (a.min(series.len()), b.min(series.len()));
        let w = &series[a..b.max(a)];
        w.iter().sum::<f64>() / w.len().max(1) as f64
    };
    let pre = window_mean(5, 15);
    let post = window_mean(30, 39);
    assert!(
        post > 0.5 * pre,
        "post-heal throughput must recover (pre {pre:.1} vs post {post:.1})"
    );

    // the baseline without the plan sees none of this
    let base = run(&small_conj(ConsistencyCfg::new(3, 1, 2)));
    assert_eq!(base.sim_stats.fault_dropped, 0);
    assert!(res.ops_ok < base.ops_ok, "the cut costs throughput");
}

#[test]
fn same_seed_and_plan_reproduce_an_identical_schedule() {
    let a = run(&partitioned_cfg());
    let b = run(&partitioned_cfg());
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert_eq!(a.sim_stats.fault_dropped, b.sim_stats.fault_dropped);
    assert_eq!(a.sim_stats.fault_transitions, b.sim_stats.fault_transitions);
    assert_eq!(a.detection_latencies_ms, b.detection_latencies_ms);
}

// ---------------------------------------------------------------------------
// crash / restart: volatile-state loss and peer re-sync
// ---------------------------------------------------------------------------

#[test]
fn crash_restart_resyncs_from_preference_list_peers() {
    let cfg = small_conj(ConsistencyCfg::n3r1w1()).with_fault_plan(FaultPlan::none().with(
        FaultEvent::Crash { server: 1, at: 15 * SEC, restart_after: 5 * SEC },
    ));
    let res = run(&cfg);
    assert_eq!(res.crashes, 1);
    assert_eq!(res.resyncs, 1, "the restarting server completed catch-up");
    assert!(
        res.resync_keys > 0,
        "peers transferred owned partitions back ({} versions)",
        res.resync_keys
    );
    assert!(res.sim_stats.fault_dropped > 0, "messages to the dead server were lost");
    assert!(res.ops_ok > 100, "R1W1 tolerates a single crashed replica");
    assert!(res.violations_detected > 0, "detection keeps working through the churn");
}

#[test]
fn crash_without_restart_stays_dark_but_the_cluster_serves() {
    let cfg = small_conj(ConsistencyCfg::n3r1w1()).with_fault_plan(
        FaultPlan::none().with(FaultEvent::Crash { server: 2, at: 10 * SEC, restart_after: 0 }),
    );
    let res = run(&cfg);
    assert_eq!(res.crashes, 1);
    assert_eq!(res.resyncs, 0, "no restart, no re-sync");
    assert!(res.ops_ok > 100, "two live replicas keep serving R1W1");
}

// ---------------------------------------------------------------------------
// detection-latency CDF (§VI): regional < 50 ms, global < 5 s at p99.9
// ---------------------------------------------------------------------------

#[test]
fn detection_cdf_regional_p999_under_50ms() {
    let res = run(&scenarios::detection_cdf_faulted(true, 0.1, 42));
    assert!(
        res.detection_cdf.len() >= 10,
        "need a population to talk about p99.9 (got {})",
        res.detection_cdf.len()
    );
    let p999 = res.detection_cdf.quantile(0.999);
    assert!(
        p999 < 50.0,
        "paper §VI: regional p99.9 detection latency < 50 ms, got {p999:.2} ms"
    );
}

#[test]
fn detection_cdf_global_p999_under_5s() {
    let res = run(&scenarios::detection_cdf_faulted(false, 0.1, 42));
    assert!(
        res.detection_cdf.len() >= 10,
        "need a population to talk about p99.9 (got {})",
        res.detection_cdf.len()
    );
    let p999 = res.detection_cdf.quantile(0.999);
    assert!(
        p999 < 5_000.0,
        "paper §VI: global p99.9 detection latency < 5 s, got {p999:.2} ms"
    );
    // the CDF field matches the raw latency list it was built from
    assert_eq!(res.detection_cdf.len(), res.detection_latencies_ms.len());
}

#[test]
fn fault_scenarios_run_end_to_end() {
    // the shipped partition scenario exercises the whole §VI story in one
    // run; small scale keeps this inside test budgets
    let res = run(&scenarios::partition_coloring(0.07, 42));
    assert!(res.ops_ok > 0, "progress under the cut");
    assert!(res.sim_stats.fault_dropped > 0, "the cut actually cut");

    let res = run(&scenarios::crash_churn_conjunctive(0.07, 42));
    assert_eq!(res.crashes, 2, "both scheduled crashes fired");
    assert_eq!(res.resyncs, 2, "both restarts caught up from peers");
    assert!(res.ops_ok > 0);
}
