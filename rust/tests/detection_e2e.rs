//! End-to-end detection tests through the full simulated deployment:
//! seeded mutual-exclusion violations are detected; correct sequential
//! executions are (essentially) violation-free; monitoring overhead stays
//! within the paper's envelope; detection latency is bounded.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::run;
use optikv::exp::scenarios;
use optikv::sim::SEC;

fn conj_cfg(consistency: ConsistencyCfg, beta: f64, seed: u64) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        "det-e2e",
        consistency,
        AppKind::Conjunctive { n_preds: 6, n_conjuncts: 4, beta, put_pct: 0.5 },
    );
    cfg.n_clients = 8;
    cfg.duration = 40 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.seed = seed;
    cfg
}

#[test]
fn conjunctive_violations_detected_with_bounded_latency() {
    let res = run(&conj_cfg(ConsistencyCfg::n3r1w1(), 0.15, 21));
    assert!(res.violations_detected >= 5, "got {}", res.violations_detected);
    // regional network: the paper reports >99.9% under 50 ms; allow a
    // generous bound for the tail (interval closure + batching)
    let over_5s = res
        .detection_latencies_ms
        .iter()
        .filter(|&&l| l > 5_000.0)
        .count();
    assert_eq!(over_5s, 0, "latencies: {:?}", res.detection_latencies_ms);
}

#[test]
fn detection_holds_on_pipelined_clients() {
    // pipeline_depth = 4: conjunctive clients overlap each flip with its
    // extra GETs; the monitors must keep detecting with bounded latency
    let res = run(&conj_cfg(ConsistencyCfg::n3r1w1(), 0.15, 21).with_pipeline_depth(4));
    assert!(res.violations_detected >= 5, "got {}", res.violations_detected);
    let over_5s = res
        .detection_latencies_ms
        .iter()
        .filter(|&&l| l > 5_000.0)
        .count();
    assert_eq!(over_5s, 0, "latencies: {:?}", res.detection_latencies_ms);
}

#[test]
fn beta_zero_means_no_violations() {
    let res = run(&conj_cfg(ConsistencyCfg::n3r1w1(), 0.0, 23));
    assert_eq!(res.violations_detected, 0);
    // linear predicates with perpetually-false conjuncts emit no candidates
    assert_eq!(res.candidates_seen, 0);
    assert!(res.ops_ok > 100, "the workload itself still ran");
}

#[test]
fn coloring_sequential_is_far_safer_than_eventual() {
    // Peterson + (quorum-)sequential consistency: the paper treats R1W3 as
    // sequential and assumes mutual exclusion holds. With client-side
    // vector-clock replication the `turn` register is NOT a linearizable
    // register under write-write races (concurrent writes become siblings
    // resolved deterministically), so *rare* actual violations remain
    // possible even at R1W3 — an honest finding of this reproduction, see
    // EXPERIMENTS.md. The robust claim: sequential shows at most a handful
    // of violations where eventual shows many (and far fewer per op).
    let mk = |c: ConsistencyCfg, seed: u64| {
        let mut cfg = scenarios::social_media_aws(c, true, 0.006, seed);
        cfg.duration = 60 * SEC;
        cfg.n_clients = 6;
        cfg
    };
    let seq = run(&mk(ConsistencyCfg::n3r1w3(), 31));
    assert!(seq.ops_ok > 300, "clients made progress: {}", seq.ops_ok);
    assert!(
        seq.actual_me_violations <= 2,
        "sequential must be (nearly) violation-free, got {}",
        seq.actual_me_violations
    );
    let ev = run(&mk(ConsistencyCfg::n3r1w1(), 31));
    let seq_rate = seq.actual_me_violations as f64 / seq.ops_ok.max(1) as f64;
    let ev_rate = ev.actual_me_violations as f64 / ev.ops_ok.max(1) as f64;
    assert!(
        ev_rate >= seq_rate,
        "eventual ({ev_rate:.2e}) must violate at least as often as sequential ({seq_rate:.2e})"
    );
}

#[test]
fn coloring_monitors_infer_edge_predicates() {
    let mut cfg = scenarios::social_media_aws(ConsistencyCfg::n3r1w1(), true, 0.006, 33);
    cfg.duration = 60 * SEC;
    cfg.n_clients = 6;
    let res = run(&cfg);
    assert!(res.active_preds_peak > 3, "peak active predicates: {}", res.active_preds_peak);
    assert!(res.candidates_seen > 0);
}

#[test]
fn monitoring_overhead_within_paper_envelope() {
    // server-perspective throughput with monitors on vs off — the paper
    // reports ≤ 8% even under stress, typically ≤ 4%
    let base = conj_cfg(ConsistencyCfg::n3r1w1(), 0.05, 41);
    let mut off = base.clone();
    off.monitors = false;
    off.name = "det-e2e-nomon".into();
    let on = run(&base);
    let noff = run(&off);
    let overhead = (noff.server_tps - on.server_tps) / noff.server_tps;
    assert!(
        overhead < 0.10,
        "overhead {:.1}% exceeds the paper's worst case (on={:.0}, off={:.0})",
        overhead * 100.0,
        on.server_tps,
        noff.server_tps
    );
}

#[test]
fn gc_reclaims_inactive_predicates() {
    // short inactive timeout: predicates idle after their burst get evicted
    let mut cfg = conj_cfg(ConsistencyCfg::n3r1w1(), 0.1, 43);
    cfg.monitor_cfg.inactive_timeout = 5 * SEC;
    cfg.monitor_cfg.gc_period = 2 * SEC;
    cfg.duration = 30 * SEC;
    let res = run(&cfg);
    assert!(res.candidates_seen > 0);
    // predicates keep being active here, so eviction may be partial — the
    // assertion is that the mechanism runs without losing detections
    assert!(res.violations_detected > 0);
}

#[cfg(feature = "accel")]
#[test]
fn xla_backend_agrees_with_native_end_to_end() {
    use optikv::exp::config::AccelKind;
    use optikv::runtime::pjrt::XlaAccel;
    if XlaAccel::load(&XlaAccel::default_dir()).is_err() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    let base = conj_cfg(ConsistencyCfg::n3r1w1(), 0.15, 45);
    let mut xla_cfg = base.clone();
    xla_cfg.accel = AccelKind::Xla;
    let native = run(&base);
    let xla = run(&xla_cfg);
    // identical seeds + identical verdict semantics ⇒ identical results
    assert_eq!(native.violations_detected, xla.violations_detected);
    assert_eq!(native.candidates_seen, xla.candidates_seen);
    assert_eq!(native.ops_ok, xla.ops_ok);
}
