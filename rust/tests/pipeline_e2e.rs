//! End-to-end tests of the client pipeline: the depth sweep actually
//! buys throughput on the scatter-gather coloring workload, the work
//! stays correct (proper colorings, conserved task accounting), and the
//! latency metrics expose the throughput/latency trade.

use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios::{pipeline_coloring, PIPELINE_DEPTHS};

fn sweep_run(depth: usize, clients: usize) -> ExpResult {
    // small but latency-dominated: thin clients on the AWS global topology
    run(&pipeline_coloring(depth, clients, 0.02, 71))
}

#[test]
fn depth8_scatter_gather_doubles_single_client_throughput() {
    // the tentpole claim: one client whose neighbor reads and deferred
    // commits travel as waves instead of deg(v) sequential round trips
    let d1 = sweep_run(1, 1);
    let d8 = sweep_run(8, 1);
    assert!(d1.ops_ok > 200, "serial baseline made progress: {}", d1.ops_ok);
    assert!(
        d8.app_tps >= 2.0 * d1.app_tps,
        "depth 8 ({:.0} ops/s) must at least double depth 1 ({:.0} ops/s)",
        d8.app_tps,
        d1.app_tps
    );
    // the pipeline overlaps ops; it must not drop or fabricate any
    assert_eq!(d8.ops_failed, 0, "no loss configured, nothing may fail");
    assert!(
        d8.metrics.borrow().tasks_completed > d1.metrics.borrow().tasks_completed,
        "more coloring tasks finish per simulated second"
    );
}

#[test]
fn sweep_is_monotone_and_exposes_latency_tradeoff() {
    let mut prev_tps = 0.0f64;
    for &d in &PIPELINE_DEPTHS {
        let res = sweep_run(d, 1);
        assert!(
            res.app_tps >= prev_tps * 0.95,
            "depth {d}: {0:.0} ops/s regressed below the shallower depth ({prev_tps:.0})",
            res.app_tps
        );
        prev_tps = res.app_tps;
        assert!(res.lat_p50_ms > 0.0, "latency percentiles recorded");
        assert!(res.lat_p99_ms >= res.lat_p50_ms);
    }
}

#[test]
fn pipelined_multi_client_coloring_still_converges() {
    // cross-client Peterson locks stay sequential inside each client; the
    // run must keep completing tasks and detecting through the monitors
    let res = sweep_run(8, 4);
    assert!(res.ops_ok > 400, "clients made progress: {}", res.ops_ok);
    assert!(res.metrics.borrow().tasks_completed > 0);
    // monitors still see the lock variables of boundary edges
    assert!(res.active_preds_peak > 0, "inferred predicates monitored");
}

#[test]
fn pipelined_runs_are_deterministic() {
    let a = sweep_run(8, 4);
    let b = sweep_run(8, 4);
    assert_eq!(a.ops_ok, b.ops_ok);
    assert_eq!(a.app_tps, b.app_tps);
    assert_eq!(a.sim_stats.events, b.sim_stats.events);
}
