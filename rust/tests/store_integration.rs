//! Integration tests of the store substrate through the full DES:
//! quorum semantics, consistency models, replica convergence/divergence,
//! timeouts and the serial second round under message loss, and the
//! partitioned (cluster > N) routing path.

use std::cell::RefCell;
use std::rc::Rc;

use optikv::client::actor::ClientActor;
use optikv::client::app::{AppOp, OpOutcome, ScriptApp};
use optikv::client::consistency::{ClientTiming, ConsistencyCfg};
use optikv::clock::hvc::EPS_INF;
use optikv::metrics::throughput::MetricsHub;
use optikv::sim::des::Sim;
use optikv::sim::net::TopologyBuilder;
use optikv::sim::{ProcId, SEC};
use optikv::store::ring::{Ring, Router, DEFAULT_RING_SEED};
use optikv::store::server::{ServerActor, ServerCfg};
use optikv::store::value::{Interner, Value};

/// Assemble `cluster` servers + `scripts.len()` clients on a 3-region
/// topology, replicating each key to `consistency.n` of them. The
/// interner must be the one the scripts' keys were interned through.
/// Returns (sim, client proc ids).
fn build(
    cluster: usize,
    consistency: ConsistencyCfg,
    interner: &Rc<RefCell<Interner>>,
    scripts: Vec<Vec<AppOp>>,
    inter_ms: f64,
    drop_prob: f64,
    seed: u64,
) -> (Sim, Vec<ProcId>) {
    build_with_depth(cluster, consistency, interner, scripts, inter_ms, drop_prob, seed, 1)
}

/// `build` with an explicit client pipeline depth.
#[allow(clippy::too_many_arguments)]
fn build_with_depth(
    cluster: usize,
    consistency: ConsistencyCfg,
    interner: &Rc<RefCell<Interner>>,
    scripts: Vec<Vec<AppOp>>,
    inter_ms: f64,
    drop_prob: f64,
    seed: u64,
    depth: usize,
) -> (Sim, Vec<ProcId>) {
    let c = scripts.len();
    let router = Router::new(
        Ring::new(cluster, consistency.n, 64, DEFAULT_RING_SEED),
        interner.clone(),
    );
    let mut tb = TopologyBuilder::new();
    for i in 0..cluster {
        tb.add_machine_proc(i as u8 % 3, 2);
    }
    for i in 0..c {
        tb.add_machine_proc(i as u8 % 3, 2);
    }
    let (topo, threads) =
        tb.build(optikv::sim::net::Topology::local_lab(inter_ms), drop_prob);
    let metrics = MetricsHub::new(cluster, c);
    let mut sim = Sim::new(topo, &threads, seed, 0.5, EPS_INF);
    let server_ids: Vec<ProcId> = (0..cluster as u32).map(ProcId).collect();
    for i in 0..cluster {
        sim.add_actor(Box::new(ServerActor::new(
            i as u16,
            router.clone(),
            None,
            ServerCfg::default(),
            metrics.clone(),
            None,
            server_ids.clone(),
        )));
    }
    let mut client_ids = Vec::new();
    for (i, script) in scripts.into_iter().enumerate() {
        let id = sim.add_actor(Box::new(ClientActor::new(
            i as u32,
            server_ids.clone(),
            router.clone(),
            consistency,
            ClientTiming::default(),
            depth,
            Box::new(ScriptApp::new(script)),
            metrics.clone(),
        )));
        client_ids.push(id);
    }
    (sim, client_ids)
}

fn outcomes(sim: &mut Sim, id: ProcId) -> Vec<OpOutcome> {
    sim.actor_mut(id)
        .as_any()
        .unwrap()
        .downcast_mut::<ClientActor>()
        .map(|_c| ())
        .unwrap();
    // outcomes live in the ScriptApp; we can't reach through ClientActor's
    // Box<dyn AppLogic> without another hook, so tests assert via ops_ok
    // counters and follow-up reads instead.
    Vec::new()
}

fn client_stats(sim: &mut Sim, id: ProcId) -> (u64, u64) {
    let c = sim
        .actor_mut(id)
        .as_any()
        .unwrap()
        .downcast_mut::<ClientActor>()
        .unwrap();
    (c.ops_ok, c.ops_failed)
}

#[test]
fn put_then_get_round_trip_sequential() {
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("k");
    let script = vec![
        AppOp::Put(k, Value::Int(41)),
        AppOp::Put(k, Value::Int(42)),
        AppOp::Get(k),
    ];
    let (mut sim, ids) = build(3, ConsistencyCfg::n3r2w2(), &interner, vec![script], 50.0, 0.0, 1);
    sim.run_until(30 * SEC);
    let (ok, failed) = client_stats(&mut sim, ids[0]);
    assert_eq!(ok, 3, "all three ops succeed");
    assert_eq!(failed, 0);
    let _ = outcomes(&mut sim, ids[0]);
}

#[test]
fn eventual_is_faster_than_sequential() {
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("k");
    let script: Vec<AppOp> = (0..50)
        .map(|i| AppOp::Put(k, Value::Int(i)))
        .collect();
    let run = |cfg: ConsistencyCfg| {
        let (mut sim, ids) = build(3, cfg, &interner, vec![script.clone()], 100.0, 0.0, 3);
        sim.run_until(200 * SEC);
        let (ok, _) = client_stats(&mut sim, ids[0]);
        assert_eq!(ok, 50);
        sim.now() // completion bounded by run_until; compare via events instead
    };
    // compare op latency via throughput over fixed horizon instead:
    let count_done = |cfg: ConsistencyCfg, horizon_s: u64| {
        let script: Vec<AppOp> = (0..10_000).map(|i| AppOp::Put(k, Value::Int(i))).collect();
        let (mut sim, ids) = build(3, cfg, &interner, vec![script], 100.0, 0.0, 3);
        sim.run_until(horizon_s * SEC);
        client_stats(&mut sim, ids[0]).0
    };
    let ev = count_done(ConsistencyCfg::n3r1w1(), 60);
    let seq = count_done(ConsistencyCfg::n3r1w3(), 60);
    assert!(
        ev as f64 > seq as f64 * 1.2,
        "eventual ({ev}) should clearly beat sequential ({seq}) at 100 ms inter-region"
    );
    let _ = run;
}

#[test]
fn sequential_read_sees_latest_write_across_clients() {
    // client 0 writes (W=3: all replicas), then client 1 reads (R=1):
    // R+W>N ⇒ the read must return the written value
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("shared");
    let w_script = vec![AppOp::Put(k, Value::Int(7))];
    let r_script = vec![
        AppOp::Get(k), // may race the write — don't assert on it
    ];
    let (mut sim, _ids) = build(
        3,
        ConsistencyCfg::n3r1w3(),
        &interner,
        vec![w_script, r_script],
        50.0,
        0.0,
        5,
    );
    sim.run_until(30 * SEC);
    // check replica convergence directly: all 3 servers hold the value
    for sidx in 0..3u32 {
        let srv = sim
            .actor_mut(ProcId(sidx))
            .as_any()
            .unwrap()
            .downcast_mut::<ServerActor>()
            .unwrap();
        let vals = srv.table().sibling_values(k);
        assert_eq!(vals, vec![Value::Int(7)], "server {sidx} converged");
    }
}

#[test]
fn eventual_write_still_replicates_asynchronously() {
    // W=1: the client returns after one ack, but the parallel-phase sends
    // reach every replica eventually (no loss here)
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("x");
    let script = vec![AppOp::Put(k, Value::Int(9))];
    let (mut sim, _) = build(3, ConsistencyCfg::n3r1w1(), &interner, vec![script], 100.0, 0.0, 9);
    sim.run_until(30 * SEC);
    for sidx in 0..3u32 {
        let srv = sim
            .actor_mut(ProcId(sidx))
            .as_any()
            .unwrap()
            .downcast_mut::<ServerActor>()
            .unwrap();
        assert_eq!(srv.table().sibling_values(k), vec![Value::Int(9)]);
    }
}

#[test]
fn message_loss_triggers_second_round_and_still_succeeds() {
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("lossy");
    let script: Vec<AppOp> = (0..20).map(|i| AppOp::Put(k, Value::Int(i))).collect();
    // 20% loss: round 1 often misses the W=3 quorum; the serial second
    // round must recover most ops
    let (mut sim, ids) = build(3, ConsistencyCfg::n3r1w3(), &interner, vec![script], 20.0, 0.2, 11);
    sim.run_until(120 * SEC);
    let (ok, failed) = client_stats(&mut sim, ids[0]);
    assert_eq!(ok + failed, 20, "every op completed or failed");
    // a single round at 20% loss passes all-3-acks only ~26% of the time;
    // the serial second round should lift success well above that
    assert!(ok >= 8, "second round recovers ops (ok={ok})");
    assert!(failed > 0, "at this loss rate some ops do fail");
}

#[test]
fn heavy_loss_hurts_sequential_far_more_than_eventual() {
    // 50% loss: W=3 needs all three replicas to ack within two rounds
    // (~8% per op); W=1 needs any one (~70%). This is the availability
    // side of the paper's motivation.
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("part");
    let script: Vec<AppOp> = (0..10).map(|i| AppOp::Put(k, Value::Int(i))).collect();
    let (mut sim, ids) = build(3, ConsistencyCfg::n3r1w3(), &interner, vec![script.clone()], 20.0, 0.5, 13);
    sim.run_until(200 * SEC);
    let (ok_seq, failed_seq) = client_stats(&mut sim, ids[0]);
    let (mut sim2, ids2) = build(3, ConsistencyCfg::n3r1w1(), &interner, vec![script], 20.0, 0.5, 13);
    sim2.run_until(200 * SEC);
    let (ok_ev, _) = client_stats(&mut sim2, ids2[0]);
    assert!(failed_seq > 0, "heavy loss must fail some W=3 ops");
    assert!(
        ok_ev >= ok_seq + 3,
        "W=1 ({ok_ev}/10) should far out-survive W=3 ({ok_seq}/10)"
    );
}

#[test]
fn concurrent_writers_create_siblings_under_eventual() {
    let interner = Interner::new();
    let k = interner.borrow_mut().intern("contested");
    // two clients write different values "simultaneously" with W=1
    let s0 = vec![AppOp::Put(k, Value::Str("A".into()))];
    let s1 = vec![AppOp::Put(k, Value::Str("B".into()))];
    let (mut sim, _) = build(3, ConsistencyCfg::n3r1w1(), &interner, vec![s0, s1], 100.0, 0.0, 17);
    sim.run_until(30 * SEC);
    // at least one replica must hold both sibling versions
    let mut saw_siblings = false;
    for sidx in 0..3u32 {
        let srv = sim
            .actor_mut(ProcId(sidx))
            .as_any()
            .unwrap()
            .downcast_mut::<ServerActor>()
            .unwrap();
        if srv.table().sibling_values(k).len() == 2 {
            saw_siblings = true;
        }
    }
    assert!(saw_siblings, "independent vector-clock writes must coexist as siblings");
}

// ---------------------------------------------------------------------------
// partitioned cluster (cluster_servers > N)
// ---------------------------------------------------------------------------

#[test]
fn partitioned_cluster_stores_keys_only_on_their_replicas() {
    let interner = Interner::new();
    let keys: Vec<_> = (0..12)
        .map(|i| interner.borrow_mut().intern(&format!("part_{i}")))
        .collect();
    let script: Vec<AppOp> = keys.iter().map(|&k| AppOp::Put(k, Value::Int(7))).collect();
    let consistency = ConsistencyCfg::n3r1w1();
    let router = Router::new(
        Ring::new(6, consistency.n, 64, DEFAULT_RING_SEED),
        interner.clone(),
    );
    let (mut sim, ids) = build(6, consistency, &interner, vec![script], 20.0, 0.0, 31);
    sim.run_until(60 * SEC);
    let (ok, failed) = client_stats(&mut sim, ids[0]);
    assert_eq!(ok, 12, "all writes reach their quorums");
    assert_eq!(failed, 0);
    for &k in &keys {
        let replicas = router.replicas(k);
        for sidx in 0..6u32 {
            let srv = sim
                .actor_mut(ProcId(sidx))
                .as_any()
                .unwrap()
                .downcast_mut::<ServerActor>()
                .unwrap();
            let present = !srv.table().sibling_values(k).is_empty();
            let owner = replicas.contains(&(sidx as u16));
            assert_eq!(
                present, owner,
                "key must live exactly on its preference list (server {sidx})"
            );
        }
    }
    // well-routed clients are never refused
    for sidx in 0..6u32 {
        let srv = sim
            .actor_mut(ProcId(sidx))
            .as_any()
            .unwrap()
            .downcast_mut::<ServerActor>()
            .unwrap();
        assert_eq!(srv.reqs_refused, 0, "server {sidx} saw only owned keys");
    }
}

#[test]
fn misrouted_requests_are_refused() {
    // a client with a stale ring view (different token seed) mis-routes
    // some keys; owners answer, non-owners refuse with WrongServer
    let interner = Interner::new();
    let keys: Vec<_> = (0..16)
        .map(|i| interner.borrow_mut().intern(&format!("stale_{i}")))
        .collect();
    let consistency = ConsistencyCfg::n3r1w1();
    let good = Router::new(
        Ring::new(6, consistency.n, 64, DEFAULT_RING_SEED),
        interner.clone(),
    );
    let stale = Router::new(Ring::new(6, consistency.n, 64, 0xBAD_5EED), interner.clone());
    // at least one key must actually be routed differently by the two views
    assert!(
        keys.iter().any(|&k| *good.replicas(k) != *stale.replicas(k)),
        "seeds happen to agree; pick another stale seed"
    );
    let mut tb = TopologyBuilder::new();
    for i in 0..6 {
        tb.add_machine_proc(i as u8 % 3, 2);
    }
    tb.add_machine_proc(0, 2); // client
    let (topo, threads) = tb.build(optikv::sim::net::Topology::local_lab(20.0), 0.0);
    let metrics = MetricsHub::new(6, 1);
    let mut sim = Sim::new(topo, &threads, 7, 0.5, EPS_INF);
    for i in 0..6 {
        sim.add_actor(Box::new(ServerActor::new(
            i as u16,
            good.clone(),
            None,
            ServerCfg::default(),
            metrics.clone(),
            None,
            (0..6u32).map(ProcId).collect(),
        )));
    }
    let script: Vec<AppOp> = keys.iter().map(|&k| AppOp::Put(k, Value::Int(1))).collect();
    let client = sim.add_actor(Box::new(ClientActor::new(
        0,
        (0..6u32).map(ProcId).collect(),
        stale,
        consistency,
        ClientTiming::default(),
        1,
        Box::new(ScriptApp::new(script)),
        metrics.clone(),
    )));
    sim.run_until(120 * SEC);
    let refused: u64 = (0..6u32)
        .map(|sidx| {
            sim.actor_mut(ProcId(sidx))
                .as_any()
                .unwrap()
                .downcast_mut::<ServerActor>()
                .unwrap()
                .reqs_refused
        })
        .sum();
    assert!(refused > 0, "stale routing must hit WrongServer refusals");
    let (ok, failed) = client_stats(&mut sim, client);
    assert_eq!(ok + failed, 16, "every op completed or failed cleanly");
}

// ---------------------------------------------------------------------------
// regression: the pipelined multiplexer reduces to the serial client
// ---------------------------------------------------------------------------

#[test]
fn serial_apps_make_pipeline_depth_inert() {
    // A closed-loop app (ScriptApp emits one op at a time) can never have
    // two calls in flight, so the multiplexer at ANY depth must reproduce
    // the serial client's event schedule exactly. This is the
    // `pipeline_depth = 1 ≡ historical serial client` regression: the
    // depth-1 code path IS this code path.
    let mk = |depth: usize| {
        let interner = Interner::new();
        let k = interner.borrow_mut().intern("serial");
        let j = interner.borrow_mut().intern("serial2");
        let script: Vec<AppOp> = (0..30)
            .flat_map(|i| [AppOp::Put(k, Value::Int(i)), AppOp::Get(j)])
            .collect();
        build_with_depth(
            3,
            ConsistencyCfg::n3r2w2(),
            &interner,
            vec![script],
            50.0,
            0.1, // loss: exercise the serial second round too
            77,
            depth,
        )
    };
    let (mut a, ids_a) = mk(1);
    let (mut b, ids_b) = mk(8);
    a.run_until(120 * SEC);
    b.run_until(120 * SEC);
    assert_eq!(
        client_stats(&mut a, ids_a[0]),
        client_stats(&mut b, ids_b[0]),
        "same ops succeed/fail at every depth"
    );
    assert_eq!(a.stats().events, b.stats().events, "identical event schedules");
    assert_eq!(a.stats().sent, b.stats().sent, "identical wire traffic");
}

// ---------------------------------------------------------------------------
// regression: cluster_servers == N reproduces full replication exactly
// ---------------------------------------------------------------------------

#[test]
fn cluster_eq_n_reproduces_full_replication_bit_identically() {
    // With cluster_servers == N every preference list is the whole (sorted)
    // server set, so the ring must be behaviorally inert: two runs with
    // wildly different ring geometry (vnodes, token seed) must produce the
    // same event schedule, op counts and violation counts as each other —
    // i.e. the partitioned code path reproduces the historical
    // full-replication behavior for every pre-existing scenario.
    use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
    use optikv::exp::runner::run;
    let mk = |vnodes: usize, ring_seed: u64| {
        let mut cfg = ExpConfig::new(
            "regress-full-replication",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
        );
        cfg.n_clients = 6;
        cfg.duration = 20 * SEC;
        cfg.topo = TopoKind::AwsRegional { zones: 3 };
        cfg.ring_vnodes = vnodes;
        cfg.ring_seed = ring_seed;
        cfg
    };
    let a = run(&mk(64, DEFAULT_RING_SEED));
    let b = run(&mk(1, 0xDEAD_BEEF));
    assert_eq!(a.ops_ok, b.ops_ok);
    assert_eq!(a.ops_failed, b.ops_failed);
    assert_eq!(a.violations_detected, b.violations_detected);
    assert_eq!(a.candidates_seen, b.candidates_seen);
    assert_eq!(a.pairs_checked, b.pairs_checked);
    assert_eq!(a.pairs_charged, b.pairs_charged);
    assert_eq!(a.app_tps, b.app_tps);
    assert_eq!(a.server_tps, b.server_tps);
    assert_eq!(a.sim_stats.events, b.sim_stats.events, "identical event schedules");
}

// ---------------------------------------------------------------------------
// regression: the clock representation is observationally pure
// ---------------------------------------------------------------------------

#[test]
fn clock_representation_is_observationally_pure() {
    // The inline HvcVec representation must be a pure re-encoding of the
    // historical heap Vec<Millis>: forcing every clock onto the heap
    // (the pre-optimization layout, via the test hook) has to reproduce
    // the exact same runs — event counts, per-class wire traffic, app
    // outcomes, violation timings — for all three workloads at pipeline
    // depth 1 and 8, same seed.
    use optikv::clock::hvc::set_force_spill;
    use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
    use optikv::exp::runner::{run, ExpResult};

    #[derive(Debug, PartialEq)]
    struct Digest {
        events: u64,
        sent: Vec<u64>,
        dropped: Vec<u64>,
        ops_ok: u64,
        ops_failed: u64,
        violations: usize,
        candidates: u64,
        pairs_checked: u64,
        pairs_charged: u64,
        app_tps_bits: u64,
        server_tps_bits: u64,
        /// the app event log: the per-bucket completion series
        app_series_bits: Vec<u64>,
        detection_ms_bits: Vec<u64>,
    }

    fn digest(r: &ExpResult) -> Digest {
        Digest {
            events: r.sim_stats.events,
            sent: r.sim_stats.sent.to_vec(),
            dropped: r.sim_stats.dropped.to_vec(),
            ops_ok: r.ops_ok,
            ops_failed: r.ops_failed,
            violations: r.violations_detected,
            candidates: r.candidates_seen,
            pairs_checked: r.pairs_checked,
            pairs_charged: r.pairs_charged,
            app_tps_bits: r.app_tps.to_bits(),
            server_tps_bits: r.server_tps.to_bits(),
            app_series_bits: r.metrics.borrow().app_series().iter().map(|x| x.to_bits()).collect(),
            detection_ms_bits: r.detection_latencies_ms.iter().map(|x| x.to_bits()).collect(),
        }
    }

    let apps: [(&str, AppKind, u64); 3] = [
        (
            "conjunctive",
            AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
            20,
        ),
        (
            "coloring",
            AppKind::Coloring { nodes: 120, edges_per_node: 3, task_size: 5, loop_forever: false },
            60,
        ),
        (
            "weather",
            AppKind::Weather { grid_w: 10, grid_h: 10, put_pct: 0.5, use_locks: true },
            30,
        ),
    ];
    for (name, app, dur_s) in apps {
        for depth in [1usize, 8] {
            let mk = || {
                let mut cfg = ExpConfig::new(
                    &format!("purity-{name}-d{depth}"),
                    ConsistencyCfg::n3r1w1(),
                    app.clone(),
                )
                .with_pipeline_depth(depth);
                cfg.n_clients = 6;
                cfg.duration = dur_s * SEC;
                cfg.topo = TopoKind::AwsRegional { zones: 3 };
                cfg
            };
            set_force_spill(false);
            let inline = run(&mk());
            set_force_spill(true);
            let spilled = run(&mk());
            set_force_spill(false);
            assert_eq!(
                digest(&inline),
                digest(&spilled),
                "representation leaked into the schedule ({name}, depth {depth})"
            );
        }
    }
}
