//! Differential test: the PJRT-executed Pallas/JAX kernels must agree
//! with the scalar Rust backend on every verdict. Skipped (with a notice)
//! when `artifacts/` has not been built yet. The whole suite requires the
//! `accel` cargo feature (xla + anyhow crates, PJRT CPU plugin).

#![cfg(feature = "accel")]

use optikv::clock::hvc::{Hvc, HvcInterval, Millis, EPS_INF};
use optikv::runtime::accel::{Accel, NativeAccel, PairQuery};
use optikv::runtime::pjrt::XlaAccel;
use optikv::util::rng::Rng;

fn artifacts_available() -> Option<XlaAccel> {
    let dir = XlaAccel::default_dir();
    match XlaAccel::load(&dir) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP: artifacts not available ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn random_interval(rng: &mut Rng, d: usize, eps_floor: bool) -> HvcInterval {
    let owner = rng.below(d as u64) as u16;
    let base = rng.range(0, 2_000) as i64;
    let mut sv: Vec<Millis> = (0..d).map(|_| base + rng.range(0, 40) as i64).collect();
    // some entries at the ε=∞ floor (unknown remote clocks)
    if eps_floor {
        for (j, x) in sv.iter_mut().enumerate() {
            if j != owner as usize && rng.chance(0.3) {
                *x = (base as i64) - EPS_INF;
            }
        }
    }
    // owner component is the process's own (max) physical time
    let own_max = *sv.iter().max().unwrap();
    sv[owner as usize] = own_max;
    let mut ev = sv.clone();
    for x in &mut ev {
        if *x > -(1 << 40) {
            *x += rng.range(0, 60) as i64;
        }
    }
    ev[owner as usize] = *ev.iter().max().unwrap();
    HvcInterval::new(Hvc::from_vec(owner, sv), Hvc::from_vec(owner, ev))
}

#[test]
fn xla_matches_native_on_random_batches() {
    let Some(mut xla) = artifacts_available() else { return };
    let mut native = NativeAccel::new();
    let mut rng = Rng::new(0xD1FF);
    for case in 0..40 {
        let d = 1 + (case % 8);
        let n = 1 + rng.below(300) as usize; // exercises padding + chunking
        let eps: Millis = match case % 4 {
            0 => 0,
            1 => 5,
            2 => 60,
            _ => EPS_INF,
        };
        let with_floors = case % 3 == 0;
        let ivs: Vec<(HvcInterval, HvcInterval)> = (0..n)
            .map(|_| {
                (
                    random_interval(&mut rng, d, with_floors),
                    random_interval(&mut rng, d, with_floors),
                )
            })
            .collect();
        let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
        let nv = native.pair_verdicts(&pairs, eps);
        let xv = xla.pair_verdicts(&pairs, eps);
        assert_eq!(nv.len(), xv.len());
        for (i, (a, b)) in nv.iter().zip(xv.iter()).enumerate() {
            assert_eq!(
                a, b,
                "case {case} pair {i} (eps={eps}): native={a:?} xla={b:?}\n  a={:?}\n  b={:?}",
                pairs[i].a, pairs[i].b
            );
        }
    }
}

#[test]
fn xla_handles_oversized_batches_by_chunking() {
    let Some(mut xla) = artifacts_available() else { return };
    let mut native = NativeAccel::new();
    let mut rng = Rng::new(7);
    let ivs: Vec<(HvcInterval, HvcInterval)> = (0..700)
        .map(|_| (random_interval(&mut rng, 5, false), random_interval(&mut rng, 5, false)))
        .collect();
    let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
    let nv = native.pair_verdicts(&pairs, 10);
    let xv = xla.pair_verdicts(&pairs, 10);
    assert_eq!(nv, xv);
    assert!(xla.calls >= 3, "700 pairs at B=256 needs >= 3 executions");
}

#[test]
fn xla_verdicts_known_cases() {
    let Some(mut xla) = artifacts_available() else { return };
    let iv = |owner: u16, s: &[Millis], e: &[Millis]| {
        HvcInterval::new(Hvc::from_vec(owner, s.to_vec()), Hvc::from_vec(owner, e.to_vec()))
    };
    let ivs = [
        iv(0, &[10, 0], &[20, 0]),
        iv(1, &[15, 15], &[15, 25]),
        iv(0, &[10, 5], &[20, 5]),
        iv(1, &[25, 40], &[25, 50]),
    ];
    let pairs = vec![
        // overlap → concurrent
        PairQuery { a: &ivs[0], b: &ivs[1] },
        // clear precedence at eps=5
        PairQuery { a: &ivs[2], b: &ivs[3] },
        // reversed
        PairQuery { a: &ivs[3], b: &ivs[2] },
    ];
    use optikv::clock::hvc::IntervalOrd::*;
    assert_eq!(xla.pair_verdicts(&pairs, 5), vec![Concurrent, Before, After]);
    // with eps = ∞ nothing is ever ordered
    assert_eq!(xla.pair_verdicts(&pairs, EPS_INF), vec![Concurrent, Concurrent, Concurrent]);
}
