//! Determinism suite for the flight recorder ([`optikv::trace`]):
//!
//! * the **disabled-recorder digest pin**: `TraceCfg::off()` (and the
//!   default config, which is the same value) reproduces pre-trace
//!   schedules bit-identically on the serial, sharded and threaded
//!   engines — including a faulted adaptive run;
//! * **trace digest identity**: with the recorder enabled, the merged
//!   trace is bit-identical across serial / merged-order sharded /
//!   threaded engines at shards {1, 2, 4, 8}, and the behavioral digest
//!   still matches the untraced run (recording is a pure side channel);
//! * the enabled recorder captures every event class end-to-end on the
//!   faulted adaptive ladder;
//! * forensics resolves every seeded violation to a non-empty causal
//!   chain whose guilty writes hit the violated candidates' keys.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios;
use optikv::sim::SEC;
use optikv::trace::forensics::Forensics;
use optikv::trace::{chrome, TraceCfg, TraceEv};

/// Everything observable a schedule change would perturb (the
/// [`sharded_determinism`] digest, minus fields the small scenarios here
/// never populate).
#[derive(Debug, PartialEq)]
struct Digest {
    events: u64,
    sent: Vec<u64>,
    ops_ok: u64,
    ops_failed: u64,
    quorum_timeouts: u64,
    violations: usize,
    candidates: u64,
    recoveries: u64,
    app_tps_bits: u64,
    detection_ms_bits: Vec<u64>,
    mode_timeline: Vec<(u64, u64, String)>,
}

fn digest(r: &ExpResult) -> Digest {
    Digest {
        events: r.sim_stats.events,
        sent: r.sim_stats.sent.to_vec(),
        ops_ok: r.ops_ok,
        ops_failed: r.ops_failed,
        quorum_timeouts: r.quorum_timeouts,
        violations: r.violations_detected,
        candidates: r.candidates_seen,
        recoveries: r.recoveries,
        app_tps_bits: r.app_tps.to_bits(),
        detection_ms_bits: r.detection_latencies_ms.iter().map(|x| x.to_bits()).collect(),
        mode_timeline: r
            .mode_timeline
            .iter()
            .map(|sp| (sp.from, sp.epoch, sp.label().to_string()))
            .collect(),
    }
}

/// The merged trace as comparable bytes: the `(at, seq)`-ordered entry
/// list plus the registry, Debug-rendered. Any reordering, loss or
/// payload difference between engines shows up here.
fn trace_digest(r: &ExpResult) -> String {
    let hub = r.trace.as_ref().expect("recorder enabled");
    let mut out = String::new();
    for (id, kind, idx) in hub.actors() {
        out.push_str(&format!("actor {id} = {kind:?}[{idx}]\n"));
    }
    for e in hub.entries() {
        out.push_str(&format!("{e:?}\n"));
    }
    out
}

/// A violation-dense conjunctive run, small enough for CI: β = 10 % over
/// 3-conjunct predicates seeds plenty of certified overlaps in 20 s.
fn small_conj(name: &str) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        name,
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 6, n_conjuncts: 3, beta: 0.1, put_pct: 0.5 },
    );
    cfg.n_clients = 6;
    cfg.duration = 20 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg
}

// ---------------------------------------------------------------------------
// the disabled-recorder digest pin
// ---------------------------------------------------------------------------

#[test]
fn off_recorder_is_digest_identical_to_pre_trace_schedules() {
    // the regression pin for the whole subsystem: a config that never
    // mentions the recorder and one that sets `TraceCfg::off()`
    // explicitly must replay bit-for-bit on every engine
    let base = || scenarios::scaleout_conjunctive(8, 0.05, 42);
    let off = || base().with_trace(TraceCfg::off());
    let want = digest(&run(&base()));
    let res = run(&off());
    assert!(res.trace.is_none(), "Off builds no hub at all");
    assert_eq!(digest(&res), want, "TraceCfg::off() perturbed the serial schedule");
    for k in [2usize, 4] {
        assert_eq!(digest(&run(&off().with_shards(k))), want, "sharded, k = {k}");
        assert_eq!(
            digest(&run(&off().with_shards(k).with_threaded())),
            want,
            "threaded, k = {k}"
        );
    }
}

#[test]
fn off_recorder_is_digest_identical_on_a_faulted_adaptive_run() {
    // the hooks sit in every actor the ladder exercises — clients,
    // servers, monitors, rollback controller, adapt controller — so the
    // faulted adaptive run is the maximal surface for an accidental
    // schedule perturbation
    let base = || scenarios::adaptive_ladder(0.1, 42);
    let off = || base().with_trace(TraceCfg::off());
    let want = digest(&run(&base()));
    assert_eq!(digest(&run(&off())), want, "serial");
    assert_eq!(digest(&run(&off().with_shards(2))), want, "sharded");
    assert_eq!(digest(&run(&off().with_shards(2).with_threaded())), want, "threaded");
}

// ---------------------------------------------------------------------------
// trace digest identity across engines
// ---------------------------------------------------------------------------

#[test]
fn traces_are_bit_identical_across_engines_at_every_shard_count() {
    // 8 servers so 8 shards get a server block each; Full mode so the
    // payloads (HVC snapshots, candidate keys) are compared too
    let mk = || scenarios::scaleout_conjunctive(8, 0.05, 42).with_trace(TraceCfg::full(1 << 16));
    let untraced = digest(&run(&scenarios::scaleout_conjunctive(8, 0.05, 42)));
    let serial = run(&mk());
    assert_eq!(digest(&serial), untraced, "an enabled recorder must not change the schedule");
    let want_trace = trace_digest(&serial);
    let want = digest(&serial);
    assert!(!serial.trace.as_ref().unwrap().is_empty(), "the run recorded events");
    for k in [1usize, 2, 4, 8] {
        let sharded = run(&mk().with_shards(k));
        assert_eq!(digest(&sharded), want, "sharded behavior, k = {k}");
        assert_eq!(trace_digest(&sharded), want_trace, "sharded trace, k = {k}");
        let threaded = run(&mk().with_shards(k).with_threaded());
        assert_eq!(digest(&threaded), want, "threaded behavior, k = {k}");
        assert_eq!(trace_digest(&threaded), want_trace, "threaded trace, k = {k}");
    }
}

#[test]
fn chrome_export_is_identical_across_engines() {
    // the export is a pure function of the merged trace, so this mostly
    // re-checks entry identity — but it also pins that actor/track
    // metadata (registered per shard) merges identically
    let mk = || small_conj("trace-chrome").with_trace(TraceCfg::full(1 << 16));
    let serial = run(&mk());
    let want_json = chrome::chrome_trace_json(serial.trace.as_ref().unwrap());
    let want_csv = chrome::signals_csv(serial.trace.as_ref().unwrap());
    let threaded = run(&mk().with_shards(2).with_threaded());
    assert_eq!(chrome::chrome_trace_json(threaded.trace.as_ref().unwrap()), want_json);
    assert_eq!(chrome::signals_csv(threaded.trace.as_ref().unwrap()), want_csv);
    assert!(want_json.starts_with("{\"displayTimeUnit\":\"ms\""));
}

// ---------------------------------------------------------------------------
// end-to-end capture and forensics
// ---------------------------------------------------------------------------

#[test]
fn traced_ladder_captures_every_event_class() {
    let res = run(&scenarios::traced_ladder(0.1, 42));
    let hub = res.trace.as_ref().expect("traced_ladder enables the recorder");
    let entries = hub.entries();
    let has = |pred: &dyn Fn(&TraceEv) -> bool| entries.iter().any(|e| pred(&e.ev));
    assert!(has(&|e| matches!(e, TraceEv::ClientIssue { .. })));
    assert!(has(&|e| matches!(e, TraceEv::ClientRound { .. })));
    assert!(has(&|e| matches!(e, TraceEv::ClientComplete { .. })));
    assert!(has(&|e| matches!(e, TraceEv::ServerApply { .. })));
    assert!(has(&|e| matches!(e, TraceEv::CandidateEmit { .. })));
    assert!(has(&|e| matches!(e, TraceEv::MonitorBatch { .. })));
    assert!(has(&|e| matches!(e, TraceEv::AdaptWindow { .. })), "controller window samples");
    assert!(
        has(&|e| matches!(e, TraceEv::ModeSwitch { .. })),
        "the partition must drive at least one switch"
    );
    // full payloads are present: some apply carries an HVC snapshot
    assert!(
        entries.iter().any(|e| matches!(&e.ev, TraceEv::ServerApply { hvc, .. } if !hvc.is_empty())),
        "Full mode records HVC snapshots"
    );
}

#[test]
fn forensics_resolves_every_seeded_violation() {
    let res = run(&small_conj("trace-forensics").with_trace(TraceCfg::full(1 << 16)));
    assert!(res.violations_detected > 0, "β = 10 % must seed violations in 20 s");
    let hub = res.trace.as_ref().unwrap();
    let forensics = Forensics::walk(hub);
    assert!(!forensics.chains.is_empty(), "every violation event yields a chain record");
    assert_eq!(forensics.empty_chains(), 0, "no violation may lose its causal chain");
    for chain in &forensics.chains {
        assert!(!chain.witnesses.is_empty());
        assert!(chain.overlap.0 <= chain.overlap.1, "certified interval overlap is real");
        for w in &chain.witnesses {
            assert!(!w.writes.is_empty(), "every witness names its guilty writes");
            for wr in &w.writes {
                assert!(
                    w.keys.contains(&wr.key),
                    "guilty write key {} outside the candidate's key set {:?}",
                    wr.key,
                    w.keys
                );
            }
        }
    }
    // the report renders without panicking and mentions the chains
    let text = forensics.render();
    assert!(text.contains("violation"), "render is human-readable: {text}");
}

#[test]
fn ring_mode_records_but_skips_payloads() {
    let res = run(&small_conj("trace-ring").with_trace(TraceCfg::ring(1 << 16)));
    let hub = res.trace.as_ref().unwrap();
    assert!(!hub.is_empty());
    for e in hub.entries() {
        match &e.ev {
            TraceEv::ServerApply { hvc, .. } => assert!(hvc.is_empty(), "Ring skips HVC snapshots"),
            TraceEv::CandidateEmit { keys, .. } => {
                assert!(keys.is_empty(), "Ring skips candidate key lists")
            }
            _ => {}
        }
    }
    // identity-only traces cannot be walked: the chains come back empty
    // rather than wrong
    let forensics = Forensics::walk(hub);
    for chain in &forensics.chains {
        assert_eq!(chain.n_writes(), 0);
    }
}
