//! End-to-end tests for the adaptive-consistency subsystem
//! ([`optikv::adapt`]): static-policy inertness (the PR's regression
//! pin), the fault-phased round trip with its throughput acceptance
//! envelope, same-seed determinism of the adaptive schedule, and epoch
//! switches interleaving with rollback freezes.

use optikv::adapt::{round_trips, AdaptCfg};
use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios::{adaptive_conjunctive, adaptive_eventual_mode, adaptive_ladder, AdaptRun};
use optikv::rollback::recovery::RecoveryPolicy;
use optikv::sim::msg::MsgClass;
use optikv::sim::SEC;

fn small_conj(consistency: ConsistencyCfg) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        "adapt-inert",
        consistency,
        AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
    );
    cfg.n_clients = 6;
    cfg.duration = 20 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg
}

/// Everything observable a schedule change would perturb.
#[derive(Debug, PartialEq)]
struct Digest {
    events: u64,
    sent: Vec<u64>,
    dropped: Vec<u64>,
    ops_ok: u64,
    ops_failed: u64,
    quorum_timeouts: u64,
    violations: usize,
    candidates: u64,
    app_tps_bits: u64,
    server_tps_bits: u64,
    app_series_bits: Vec<u64>,
    detection_ms_bits: Vec<u64>,
}

fn digest(r: &ExpResult) -> Digest {
    Digest {
        events: r.sim_stats.events,
        sent: r.sim_stats.sent.to_vec(),
        dropped: r.sim_stats.dropped.to_vec(),
        ops_ok: r.ops_ok,
        ops_failed: r.ops_failed,
        quorum_timeouts: r.quorum_timeouts,
        violations: r.violations_detected,
        candidates: r.candidates_seen,
        app_tps_bits: r.app_tps.to_bits(),
        server_tps_bits: r.server_tps.to_bits(),
        app_series_bits: r.metrics.borrow().app_series().iter().map(|x| x.to_bits()).collect(),
        detection_ms_bits: r.detection_latencies_ms.iter().map(|x| x.to_bits()).collect(),
    }
}

// ---------------------------------------------------------------------------
// regression pin: the static policy (the default) is inert
// ---------------------------------------------------------------------------

#[test]
fn static_policy_is_bit_identical_and_silent() {
    // The default ExpConfig carries `AdaptCfg::static_default()`; setting
    // it explicitly must change nothing — no adapt actor is deployed, no
    // adapt message is ever sent, and the event schedule is identical.
    // (This is the `pipeline_depth = 1` / `FaultPlan::none()` discipline
    // for the adapt knob.)
    for consistency in [ConsistencyCfg::n3r1w1(), ConsistencyCfg::n3r2w2()] {
        let implicit = run(&small_conj(consistency));
        let explicit = run(&small_conj(consistency).with_adapt(AdaptCfg::static_default()));
        assert_eq!(
            digest(&implicit),
            digest(&explicit),
            "explicit static adapt config must be inert ({})",
            consistency.label()
        );
        for r in [&implicit, &explicit] {
            assert_eq!(
                r.sim_stats.sent_class(MsgClass::Adapt),
                0,
                "no adapt traffic without a controller"
            );
            assert_eq!(r.mode_switches, 0);
            assert_eq!(r.mode_timeline.len(), 1, "one static span covers the run");
            assert_eq!(r.mode_timeline[0].cfg, consistency);
            assert_eq!(r.mode_timeline[0].epoch, 0);
            assert_eq!(r.per_mode_tps.len(), 1, "a single mode was ever active");
            assert_eq!(r.per_mode_tps[0].0, consistency.model_name());
        }
    }
}

// ---------------------------------------------------------------------------
// the fault-phased scenario: round trip + throughput acceptance envelope
// ---------------------------------------------------------------------------

#[test]
fn hysteresis_round_trips_and_stays_within_the_static_envelope() {
    let scale = 0.1;
    let seed = 42;
    let adaptive = run(&adaptive_conjunctive(AdaptRun::Adaptive, scale, seed));
    let st_ev = run(&adaptive_conjunctive(AdaptRun::StaticEventual, scale, seed));
    let st_seq = run(&adaptive_conjunctive(AdaptRun::StaticSequential, scale, seed));

    // the partition makes W = 2 writes from the cut region expire: the
    // signal the controller trips on must actually exist
    assert!(st_ev.quorum_timeouts > 0, "the cut must surface as quorum timeouts");
    assert!(st_ev.ops_failed > 0, "cut-region writes fail under the eventual pin");

    // mode timeline: starts eventual, drops to sequential during the bad
    // phase, returns to eventual after heal
    assert_eq!(adaptive.mode_timeline[0].cfg, adaptive_eventual_mode());
    assert!(
        adaptive.mode_switches >= 2,
        "up- and down-switch expected, got {} (timeline {:?})",
        adaptive.mode_switches,
        adaptive.mode_timeline
    );
    assert!(
        round_trips(&adaptive.mode_timeline) >= 1,
        "eventual→sequential→eventual round trip expected: {:?}",
        adaptive.mode_timeline
    );
    let last = adaptive.mode_timeline.last().unwrap();
    assert!(last.cfg.is_eventual(), "the cluster ends back in the eventual mode");
    assert!(
        adaptive.sim_stats.sent_class(MsgClass::Adapt) > 0,
        "announce/ack traffic flowed"
    );

    // epochs on the timeline are strictly increasing from 0
    for (i, sp) in adaptive.mode_timeline.iter().enumerate() {
        assert_eq!(sp.epoch, i as u64, "epochs advance one switch at a time");
    }

    // both modes accumulated fully-covered windows
    let labels: Vec<&str> = adaptive.per_mode_tps.iter().map(|(l, _)| l.as_str()).collect();
    assert!(labels.contains(&"eventual") && labels.contains(&"sequential"), "{labels:?}");

    // the acceptance envelope: adaptive >= max(static pins) - 5 %
    let best_static = st_ev.app_tps.max(st_seq.app_tps);
    assert!(
        adaptive.app_tps >= best_static * 0.95,
        "adaptive ({:.1} ops/s) fell below best static ({:.1} ops/s) - 5%",
        adaptive.app_tps,
        best_static
    );
}

// ---------------------------------------------------------------------------
// the three-level ladder: eventual → causal → sequential and back
// ---------------------------------------------------------------------------

#[test]
fn ladder_walks_the_causal_rung_both_ways_one_step_at_a_time() {
    let res = run(&adaptive_ladder(0.1, 42));
    let labels: Vec<&str> = res.mode_timeline.iter().map(|sp| sp.label()).collect();
    assert_eq!(labels.first(), Some(&"eventual"), "starts on the bottom rung");
    assert!(labels.contains(&"causal"), "the middle rung was visited: {labels:?}");
    assert!(labels.contains(&"sequential"), "the cut drove a full escalation: {labels:?}");
    assert_eq!(labels.last(), Some(&"eventual"), "full descent after heal: {labels:?}");
    assert!(res.mode_switches >= 4, "two rungs up, two down: {labels:?}");

    // one rung per switch: no adjacent pair of spans ever skips a level
    let rung = |l: &str| match l {
        "eventual" => 0i64,
        "causal" => 1,
        _ => 2,
    };
    for w in res.mode_timeline.windows(2) {
        assert_eq!(
            (rung(w[0].label()) - rung(w[1].label())).abs(),
            1,
            "switches move one rung at a time: {labels:?}"
        );
    }

    // the causal rung keeps the eventual quorum math — only the
    // session-guarantee flag distinguishes its announced config
    for sp in res.mode_timeline.iter().filter(|sp| sp.label() == "causal") {
        assert!(sp.cfg.is_eventual() && sp.cfg.causal);
        assert_eq!(sp.cfg.label(), "N3R1W2-causal");
    }

    assert!(
        res.sim_stats.sent_class(MsgClass::Adapt) > 0,
        "announce/ack/set-recovery traffic flowed"
    );
    assert!(res.ops_ok > 100, "the cluster made progress: {}", res.ops_ok);

    // the ladder schedule replays under the seed
    let again = run(&adaptive_ladder(0.1, 42));
    assert_eq!(res.mode_timeline, again.mode_timeline);
    assert_eq!(digest(&res), digest(&again));
}

// ---------------------------------------------------------------------------
// determinism: the adaptive schedule replays under a seed
// ---------------------------------------------------------------------------

#[test]
fn adaptive_schedule_is_deterministic_under_seed() {
    let mk = || adaptive_conjunctive(AdaptRun::Adaptive, 0.1, 7);
    let a = run(&mk());
    let b = run(&mk());
    assert_eq!(digest(&a), digest(&b));
    assert_eq!(a.mode_timeline, b.mode_timeline, "identical switch times and epochs");
    assert_eq!(a.mode_switches, b.mode_switches);
    assert_eq!(a.per_mode_tps, b.per_mode_tps);
}

// ---------------------------------------------------------------------------
// epoch switches interleaving with rollback freezes
// ---------------------------------------------------------------------------

#[test]
fn switches_stay_sound_while_rollback_freezes_are_active() {
    // FullRestore + a hot violation rate (β = 0.2): recoveries freeze the
    // servers from early in the run. When the partition opens, a freeze
    // eventually targets the unreachable server and wedges the rollback
    // controller mid-recovery (the documented FullRestore-under-partition
    // behavior, DESIGN.md §7) — with servers frozen, every quorum round
    // expires, the timeout signal saturates, and the adapt controller
    // announces its switch *while the freeze is active*. The protocol
    // must stay sound: clients (which never freeze) ack the epoch, the
    // schedule replays under the seed, and nothing deadlocks or panics.
    let mk = || {
        let mut cfg = adaptive_conjunctive(AdaptRun::Adaptive, 0.1, 11);
        cfg.app = AppKind::Conjunctive { n_preds: 8, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 };
        cfg.recovery = RecoveryPolicy::FullRestore;
        cfg
    };
    let res = run(&mk());
    assert!(res.recoveries >= 1, "freezes happened");
    assert!(res.mode_switches >= 1, "a switch was announced during the degraded phase");
    assert!(res.ops_ok > 100, "pre-cut progress exists: {}", res.ops_ok);
    assert!(res.ops_failed > 0, "frozen/unreachable servers fail quorums");
    assert!(
        res.sim_stats.sent_class(MsgClass::Adapt) > 0,
        "announces and acks flowed while servers were frozen"
    );

    let again = run(&mk());
    assert_eq!(digest(&res), digest(&again));
    assert_eq!(res.mode_timeline, again.mode_timeline);
}
