//! Determinism suite for the sharded event loop ([`optikv::sim::des`],
//! [`optikv::sim::shard`]):
//!
//! * the merged-order sharded engine is **bit-identical to the serial
//!   engine at every shard count** — on all three workloads and under
//!   fault injection (the PR's regression pin: `shards = 1` reproduces
//!   the pre-change serial schedules event-for-event);
//! * the calendar-queue scheduler produces the same schedules as the
//!   binary heap;
//! * the **threaded engine runs the full production stack** — servers,
//!   monitors, clients, rollback controller, fault injection, adaptive
//!   consistency — and is bit-identical to the serial engine at every
//!   shard count, including repeat runs of the same config.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios::{self, AdaptRun};
use optikv::sim::des::SchedKind;
use optikv::sim::SEC;

/// Everything observable a schedule change would perturb. Deliberately
/// excludes `barriers` / `shard_events` / `lookahead` / `shard_actors` —
/// those are engine telemetry that legitimately varies with the shard
/// count.
#[derive(Debug, PartialEq)]
struct Digest {
    events: u64,
    sent: Vec<u64>,
    dropped: Vec<u64>,
    ops_ok: u64,
    ops_failed: u64,
    rejoins: u64,
    quorum_timeouts: u64,
    violations: usize,
    actual_violations: usize,
    candidates: u64,
    recoveries: u64,
    crashes: u64,
    app_tps_bits: u64,
    server_tps_bits: u64,
    app_series_bits: Vec<u64>,
    detection_ms_bits: Vec<u64>,
    /// announced consistency epochs: (from, epoch, model label) — pins
    /// the adapt controller's decisions, not just their count
    mode_timeline: Vec<(u64, u64, String)>,
    mode_switches: u64,
}

fn digest(r: &ExpResult) -> Digest {
    Digest {
        events: r.sim_stats.events,
        sent: r.sim_stats.sent.to_vec(),
        dropped: r.sim_stats.dropped.to_vec(),
        ops_ok: r.ops_ok,
        ops_failed: r.ops_failed,
        rejoins: r.rejoins,
        quorum_timeouts: r.quorum_timeouts,
        violations: r.violations_detected,
        actual_violations: r.actual_me_violations,
        candidates: r.candidates_seen,
        recoveries: r.recoveries,
        crashes: r.crashes,
        app_tps_bits: r.app_tps.to_bits(),
        server_tps_bits: r.server_tps.to_bits(),
        app_series_bits: r.metrics.borrow().app_series().iter().map(|x| x.to_bits()).collect(),
        detection_ms_bits: r.detection_latencies_ms.iter().map(|x| x.to_bits()).collect(),
        mode_timeline: r
            .mode_timeline
            .iter()
            .map(|sp| (sp.from, sp.epoch, sp.label().to_string()))
            .collect(),
        mode_switches: r.mode_switches,
    }
}

/// Assert the full digest is bit-identical between the serial engine and
/// the merged-order sharded engine at each of `shard_counts`, and that
/// the sharded runs actually exercised the window protocol.
fn assert_shards_match_serial(mk: impl Fn() -> ExpConfig, shard_counts: &[usize]) {
    let serial = run(&mk());
    let want = digest(&serial);
    assert_eq!(serial.barriers, 0, "serial engine runs no windows");
    assert!(serial.shard_events.is_empty());
    for &k in shard_counts {
        let res = run(&mk().with_shards(k));
        assert_eq!(digest(&res), want, "shards = {k} diverged from serial");
        assert!(res.barriers > 0, "shards = {k} never hit a window barrier");
        assert_eq!(
            res.shard_events.iter().sum::<u64>(),
            res.sim_stats.events,
            "every event is attributed to exactly one shard"
        );
        if k > 1 {
            assert!(
                res.shard_events.iter().filter(|&&e| e > 0).count() > 1,
                "shards = {k}: work actually spread across shards: {:?}",
                res.shard_events
            );
        }
    }
}

/// Assert the full digest is bit-identical between the serial engine and
/// the **threaded** engine (worker threads + conservative windows) at
/// each of `shard_counts`.
fn assert_threaded_matches_serial(mk: impl Fn() -> ExpConfig, shard_counts: &[usize]) {
    let serial = run(&mk());
    let want = digest(&serial);
    for &k in shard_counts {
        let res = run(&mk().with_shards(k).with_threaded());
        assert_eq!(digest(&res), want, "threaded shards = {k} diverged from serial");
        assert!(res.barriers > 0, "threaded shards = {k} ran no window barriers");
        assert_eq!(
            res.shard_events.iter().sum::<u64>(),
            res.sim_stats.events,
            "every event is attributed to exactly one worker"
        );
        assert!(res.lookahead > 0, "the plan reports its conservative window");
        assert_eq!(res.shard_actors.len(), res.shard_events.len());
        assert!(
            res.shard_actors.iter().all(|&n| n > 0),
            "every worker hosts at least one actor: {:?}",
            res.shard_actors
        );
    }
}

// ---------------------------------------------------------------------------
// the regression pin, on all three workloads
// ---------------------------------------------------------------------------

#[test]
fn conjunctive_scaleout_is_bit_identical_at_every_shard_count() {
    // 8 servers so 8 shards get a server block each; the full stack:
    // partitioned routing, monitors, rollback controller
    assert_shards_match_serial(|| scenarios::scaleout_conjunctive(8, 0.05, 42), &[1, 2, 4, 8]);
}

#[test]
fn coloring_is_bit_identical_at_every_shard_count() {
    let mk = || {
        let mut cfg = ExpConfig::new(
            "shard-coloring",
            ConsistencyCfg::n3r1w1(),
            AppKind::Coloring { nodes: 120, edges_per_node: 3, task_size: 5, loop_forever: true },
        );
        cfg.n_clients = 6;
        cfg.duration = 20 * SEC;
        cfg.topo = TopoKind::AwsRegional { zones: 3 };
        cfg
    };
    // 3 servers: k clamps to 3, and asking for 4 must behave like 3
    assert_shards_match_serial(mk, &[1, 2, 3, 4]);
}

#[test]
fn weather_is_bit_identical_at_every_shard_count() {
    let mk = || {
        let mut cfg = ExpConfig::new(
            "shard-weather",
            ConsistencyCfg::n3r1w1(),
            AppKind::Weather { grid_w: 10, grid_h: 10, put_pct: 0.5, use_locks: true },
        );
        cfg.n_clients = 6;
        cfg.duration = 20 * SEC;
        cfg.topo = TopoKind::AwsRegional { zones: 3 };
        cfg
    };
    assert_shards_match_serial(mk, &[1, 2, 3]);
}

#[test]
fn faulted_run_is_bit_identical_at_every_shard_count() {
    // crash/restart churn + peer re-sync: fault transitions interleave
    // with window boundaries and must not reorder anything
    assert_shards_match_serial(|| scenarios::crash_churn_conjunctive(0.05, 42), &[1, 2]);
}

// ---------------------------------------------------------------------------
// scheduler structure: calendar queue == binary heap
// ---------------------------------------------------------------------------

#[test]
fn calendar_queue_reproduces_heap_schedules() {
    let mk = || scenarios::scaleout_conjunctive(6, 0.05, 42);
    let serial = run(&mk());
    let heap = run(&mk().with_shards(2).with_sched(SchedKind::Heap));
    let cal = run(&mk().with_shards(2).with_sched(SchedKind::Calendar));
    assert_eq!(digest(&heap), digest(&serial));
    assert_eq!(digest(&cal), digest(&serial), "calendar queue changed the schedule");
}

// ---------------------------------------------------------------------------
// the threaded engine: the full production stack on worker threads
// ---------------------------------------------------------------------------

#[test]
fn threaded_full_stack_is_bit_identical_at_every_shard_count() {
    // 8 servers so 8 workers get a server block each — the whole
    // production deployment on threads, digest-equal to serial
    assert_threaded_matches_serial(
        || scenarios::scaleout_conjunctive(8, 0.05, 42),
        &[1, 2, 4, 8],
    );
}

#[test]
fn threaded_faulted_run_is_bit_identical() {
    // crash/restart churn: every worker tracks the global fault view,
    // only the owning worker delivers lifecycle hooks — digests agree
    assert_threaded_matches_serial(|| scenarios::crash_churn_conjunctive(0.05, 42), &[1, 2]);
}

#[test]
fn threaded_adaptive_run_is_bit_identical() {
    // the adapt controller lives on worker 0, its clients everywhere:
    // report/announce/ack traffic crosses shard boundaries and the
    // announced mode timeline must still be bit-identical
    assert_threaded_matches_serial(
        || scenarios::adaptive_conjunctive(AdaptRun::Adaptive, 0.05, 42),
        &[1, 2],
    );
}

#[test]
fn causal_mode_is_bit_identical_on_all_engines() {
    // client-side session guarantees: the causal floor patches GET
    // results purely from client-local state — no extra protocol
    // traffic, no RNG draws — so a causal run must replay bit-for-bit
    // on the serial, sharded and threaded engines alike
    let mk = || {
        let mut cfg = scenarios::scaleout_conjunctive(8, 0.05, 42);
        cfg.consistency = ConsistencyCfg::n3r1w1().with_causal();
        cfg
    };
    assert_shards_match_serial(mk, &[1, 2, 4]);
    assert_threaded_matches_serial(mk, &[1, 2]);
}

#[test]
fn adaptive_ladder_is_bit_identical_threaded() {
    // the full three-level composition — hysteresis3 walking the causal
    // rung, session floors appearing and dropping with announces, and
    // per-mode recovery pushes to the rollback controller on worker 0 —
    // still digest-equal across engines
    assert_threaded_matches_serial(|| scenarios::adaptive_ladder(0.05, 42), &[1, 2]);
}

// ---------------------------------------------------------------------------
// the workload engine: inert default, skewed traffic, churn, flash crowd
// ---------------------------------------------------------------------------

#[test]
fn inert_workload_default_changes_nothing_and_stays_identical() {
    // the regression pin for the workload subsystem: attaching the
    // explicit uniform default must reproduce the plain run bit-for-bit
    // (zero extra RNG draws, zero event changes), on every engine
    let base = || scenarios::scaleout_conjunctive(8, 0.05, 42);
    let with_default = || base().with_workload(optikv::workload::WorkloadCfg::uniform_default());
    assert_eq!(
        digest(&run(&base())),
        digest(&run(&with_default())),
        "uniform_default() must be inert"
    );
    assert_shards_match_serial(with_default, &[1, 4]);
    assert_threaded_matches_serial(with_default, &[1, 4]);
}

#[test]
fn kvmix_zipf_is_bit_identical_on_all_engines() {
    // skewed production traffic: alias-table draws, guarded hot keys and
    // per-key metrics all merge back to the serial schedule
    let mk = || scenarios::kvmix_skew(1.2, AdaptRun::StaticEventual, 0.05, 42);
    assert_shards_match_serial(mk, &[1, 2]);
    assert_threaded_matches_serial(mk, &[1, 2]);
}

#[test]
fn kvmix_churn_is_bit_identical_threaded() {
    // client leave/rejoin rides the fault timeline: every worker replays
    // the same merged schedule, only the owning shard delivers the hooks
    let mk = || scenarios::kvmix_churn(AdaptRun::StaticEventual, 0.05, 42);
    assert_threaded_matches_serial(mk, &[1, 2]);
}

#[test]
fn kvmix_flash_crowd_adaptive_is_bit_identical_threaded() {
    // the full composition — load shape + partition + hysteresis
    // controller — still digest-equal across engines
    assert_threaded_matches_serial(
        || scenarios::kvmix_flash_crowd(AdaptRun::Adaptive, true, 0.05, 42),
        &[2],
    );
}

#[test]
fn threaded_run_is_reproducible() {
    // thread scheduling must leak nothing: the same config twice gives
    // the same digest AND the same engine telemetry
    let mk = || scenarios::scaleout_conjunctive(8, 0.05, 42).with_shards(4).with_threaded();
    let a = run(&mk());
    let b = run(&mk());
    assert_eq!(digest(&a), digest(&b));
    assert_eq!(a.barriers, b.barriers);
    assert_eq!(a.shard_events, b.shard_events);
    assert_eq!(a.lookahead, b.lookahead);
    assert_eq!(a.shard_actors, b.shard_actors);
}
