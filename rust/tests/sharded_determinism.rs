//! Determinism suite for the sharded event loop ([`optikv::sim::des`],
//! [`optikv::sim::shard`]):
//!
//! * the merged-order sharded engine is **bit-identical to the serial
//!   engine at every shard count** — on all three workloads and under
//!   fault injection (the PR's regression pin: `shards = 1` reproduces
//!   the pre-change serial schedules event-for-event);
//! * the calendar-queue scheduler produces the same schedules as the
//!   binary heap;
//! * the threaded engine's runs are a function of (workload, seed)
//!   only: same-seed reproducible and invariant under the shard count.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios;
use optikv::sim::des::SchedKind;
use optikv::sim::shard::{run_demo, DemoSpec};
use optikv::sim::SEC;

/// Everything observable a schedule change would perturb. Deliberately
/// excludes `barriers` / `shard_events` — those are engine telemetry
/// that legitimately varies with the shard count.
#[derive(Debug, PartialEq)]
struct Digest {
    events: u64,
    sent: Vec<u64>,
    dropped: Vec<u64>,
    ops_ok: u64,
    ops_failed: u64,
    quorum_timeouts: u64,
    violations: usize,
    candidates: u64,
    app_tps_bits: u64,
    server_tps_bits: u64,
    app_series_bits: Vec<u64>,
    detection_ms_bits: Vec<u64>,
}

fn digest(r: &ExpResult) -> Digest {
    Digest {
        events: r.sim_stats.events,
        sent: r.sim_stats.sent.to_vec(),
        dropped: r.sim_stats.dropped.to_vec(),
        ops_ok: r.ops_ok,
        ops_failed: r.ops_failed,
        quorum_timeouts: r.quorum_timeouts,
        violations: r.violations_detected,
        candidates: r.candidates_seen,
        app_tps_bits: r.app_tps.to_bits(),
        server_tps_bits: r.server_tps.to_bits(),
        app_series_bits: r.metrics.borrow().app_series().iter().map(|x| x.to_bits()).collect(),
        detection_ms_bits: r.detection_latencies_ms.iter().map(|x| x.to_bits()).collect(),
    }
}

/// Assert the full digest is bit-identical between the serial engine and
/// the merged-order sharded engine at each of `shard_counts`, and that
/// the sharded runs actually exercised the window protocol.
fn assert_shards_match_serial(mk: impl Fn() -> ExpConfig, shard_counts: &[usize]) {
    let serial = run(&mk());
    let want = digest(&serial);
    assert_eq!(serial.barriers, 0, "serial engine runs no windows");
    assert!(serial.shard_events.is_empty());
    for &k in shard_counts {
        let res = run(&mk().with_shards(k));
        assert_eq!(digest(&res), want, "shards = {k} diverged from serial");
        assert!(res.barriers > 0, "shards = {k} never hit a window barrier");
        assert_eq!(
            res.shard_events.iter().sum::<u64>(),
            res.sim_stats.events,
            "every event is attributed to exactly one shard"
        );
        if k > 1 {
            assert!(
                res.shard_events.iter().filter(|&&e| e > 0).count() > 1,
                "shards = {k}: work actually spread across shards: {:?}",
                res.shard_events
            );
        }
    }
}

// ---------------------------------------------------------------------------
// the regression pin, on all three workloads
// ---------------------------------------------------------------------------

#[test]
fn conjunctive_scaleout_is_bit_identical_at_every_shard_count() {
    // 8 servers so 8 shards get a server block each; the full stack:
    // partitioned routing, monitors, rollback controller
    assert_shards_match_serial(|| scenarios::scaleout_conjunctive(8, 0.05, 42), &[1, 2, 4, 8]);
}

#[test]
fn coloring_is_bit_identical_at_every_shard_count() {
    let mk = || {
        let mut cfg = ExpConfig::new(
            "shard-coloring",
            ConsistencyCfg::n3r1w1(),
            AppKind::Coloring { nodes: 120, edges_per_node: 3, task_size: 5, loop_forever: true },
        );
        cfg.n_clients = 6;
        cfg.duration = 20 * SEC;
        cfg.topo = TopoKind::AwsRegional { zones: 3 };
        cfg
    };
    // 3 servers: k clamps to 3, and asking for 4 must behave like 3
    assert_shards_match_serial(mk, &[1, 2, 3, 4]);
}

#[test]
fn weather_is_bit_identical_at_every_shard_count() {
    let mk = || {
        let mut cfg = ExpConfig::new(
            "shard-weather",
            ConsistencyCfg::n3r1w1(),
            AppKind::Weather { grid_w: 10, grid_h: 10, put_pct: 0.5, use_locks: true },
        );
        cfg.n_clients = 6;
        cfg.duration = 20 * SEC;
        cfg.topo = TopoKind::AwsRegional { zones: 3 };
        cfg
    };
    assert_shards_match_serial(mk, &[1, 2, 3]);
}

#[test]
fn faulted_run_is_bit_identical_at_every_shard_count() {
    // crash/restart churn + peer re-sync: fault transitions interleave
    // with window boundaries and must not reorder anything
    assert_shards_match_serial(|| scenarios::crash_churn_conjunctive(0.05, 42), &[1, 2]);
}

// ---------------------------------------------------------------------------
// scheduler structure: calendar queue == binary heap
// ---------------------------------------------------------------------------

#[test]
fn calendar_queue_reproduces_heap_schedules() {
    let mk = || scenarios::scaleout_conjunctive(6, 0.05, 42);
    let serial = run(&mk());
    let heap = run(&mk().with_shards(2).with_sched(SchedKind::Heap));
    let cal = run(&mk().with_shards(2).with_sched(SchedKind::Calendar));
    assert_eq!(digest(&heap), digest(&serial));
    assert_eq!(digest(&cal), digest(&serial), "calendar queue changed the schedule");
}

// ---------------------------------------------------------------------------
// the threaded engine
// ---------------------------------------------------------------------------

#[test]
fn threaded_demo_is_reproducible_and_shard_count_invariant() {
    let spec = DemoSpec::s24(42);
    let until = 2 * SEC;
    let base = run_demo(&spec, 1, until, SchedKind::Heap);
    assert!(base.ops > 1_000, "the mill turned: {} ops", base.ops);
    for k in [2usize, 4] {
        let r = run_demo(&spec, k, until, SchedKind::Heap);
        assert_eq!(r.ops, base.ops, "shards = {k}");
        assert_eq!(r.stats.events, base.stats.events, "shards = {k}");
        assert_eq!(r.stats.sent, base.stats.sent, "shards = {k}");
        assert_eq!(r.stats.dropped, base.stats.dropped, "shards = {k}");
        assert!(r.barriers > 0);
        assert_eq!(r.per_shard_events.iter().sum::<u64>(), r.stats.events);
        // and the same run again, bit-for-bit
        let again = run_demo(&spec, k, until, SchedKind::Heap);
        assert_eq!(again.ops, r.ops);
        assert_eq!(again.stats.events, r.stats.events);
        assert_eq!(again.per_shard_events, r.per_shard_events);
        assert_eq!(again.barriers, r.barriers);
    }
}
