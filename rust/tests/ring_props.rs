//! Property tests for the consistent-hash partitioning ring: preference
//! lists are well-formed, the key→replica assignment is a pure function
//! of the ring parameters (stable under reconstruction), and per-server
//! load stays near-uniform at the default vnode count.

use optikv::exp::scenarios::SCALEOUT_SIZES;
use optikv::predicate::infer;
use optikv::store::ring::{mix64, route_hash, Ring, DEFAULT_RING_SEED, DEFAULT_VNODES};
use optikv::util::prop;

#[test]
fn prop_preference_lists_have_exactly_n_distinct_servers() {
    prop::check_default("ring_pref_list_shape", |rng| {
        let s = rng.range(1, 33) as usize;
        let n = rng.range(1, (s + 1) as u64) as usize;
        let vnodes = rng.range(1, 129) as usize;
        let ring = Ring::new(s, n, vnodes, rng.next_u64());
        for _ in 0..32 {
            let h = rng.next_u64();
            let list = ring.preference_list(h);
            if list.len() != n {
                return Err(format!("expected {n} replicas, got {list:?}"));
            }
            let mut d = list.clone();
            d.sort_unstable();
            d.dedup();
            if d.len() != n {
                return Err(format!("duplicate servers in {list:?}"));
            }
            if d.iter().any(|&x| x as usize >= s) {
                return Err(format!("server out of range in {list:?} (cluster {s})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_assignment_stable_under_reconstruction() {
    prop::check_default("ring_reconstruction_stable", |rng| {
        let s = rng.range(2, 25) as usize;
        let n = rng.range(1, (s.min(5) + 1) as u64) as usize;
        let vnodes = rng.range(1, 65) as usize;
        let seed = rng.next_u64();
        let a = Ring::new(s, n, vnodes, seed);
        let b = Ring::new(s, n, vnodes, seed);
        for _ in 0..64 {
            let h = rng.next_u64();
            if a.preference_list(h) != b.preference_list(h) {
                return Err(format!("reconstruction moved the replicas of {h:#x}"));
            }
            if a.primary(h) != b.primary(h) {
                return Err(format!("reconstruction moved the primary of {h:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ownership_consistent_with_preference_list() {
    prop::check_default("ring_ownership_consistent", |rng| {
        let s = rng.range(2, 17) as usize;
        let n = rng.range(1, (s.min(4) + 1) as u64) as usize;
        let ring = Ring::new(s, n, 16, rng.next_u64());
        for _ in 0..16 {
            let h = rng.next_u64();
            let list = ring.preference_list(h);
            for srv in 0..s as u16 {
                if ring.owns(srv, h) != list.contains(&srv) {
                    return Err(format!("owns({srv}) disagrees with {list:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn load_balanced_within_15pct_at_default_vnodes() {
    // the shipped default seed keeps replica-set load within ~15% of
    // uniform for every scale-out cluster size (vnode rings concentrate
    // like 1/sqrt(vnodes); the default seed was picked to sit comfortably
    // inside the bound at 64 vnodes)
    for &s in &SCALEOUT_SIZES {
        let n = 3;
        let ring = Ring::new(s, n, DEFAULT_VNODES, DEFAULT_RING_SEED);
        let n_keys = 20_000u64;
        let mut counts = vec![0u64; s];
        for i in 0..n_keys {
            for srv in ring.preference_list(mix64(0xBA5E ^ i)) {
                counts[srv as usize] += 1;
            }
        }
        let mean = (n_keys * n as u64) as f64 / s as f64;
        for (srv, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - mean).abs() / mean;
            assert!(
                dev <= 0.15,
                "cluster {s}: server {srv} carries {c} of mean {mean:.0} ({:.1}% off)",
                dev * 100.0
            );
        }
    }
}

#[test]
fn prop_lock_variables_route_together() {
    prop::check_default("ring_lock_colocation", |rng| {
        let a = rng.range(0, 1_000);
        let b = rng.range(a + 1, a + 1_000);
        let fa = route_hash(&infer::flag_name(a, b, a));
        let fb = route_hash(&infer::flag_name(a, b, b));
        let t = route_hash(&infer::turn_name(a, b));
        if fa != fb || fa != t {
            return Err(format!("edge ({a},{b}) lock vars route apart"));
        }
        // a neighboring edge must not collapse onto the same tag
        let other = route_hash(&infer::turn_name(a, b + 1));
        if other == fa {
            return Err(format!("edges ({a},{b}) and ({a},{})) collide", b + 1));
        }
        Ok(())
    });
}
