//! End-to-end tests for the production-traffic workload engine
//! ([`optikv::workload`] + [`optikv::apps::kvmix`]):
//!
//! * **churn under server crash** — a client leave/rejoin schedule
//!   composed with a server crash on the *same* fault timeline: the
//!   departed client's per-window op counts go dark exactly while it is
//!   gone, the rejoin is counted, and the whole composition is
//!   bit-identical on the threaded engine;
//! * **skew → violation rate** — the acceptance claim that the
//!   mutual-exclusion violation rate (per kop) is monotone in the Zipf
//!   parameter, checked at the sweep endpoints;
//! * **flash crowd round trip** — the adaptive controller escalates to
//!   sequential during the partitioned flash crowd and releases after
//!   the heal (≥ 1 full round trip), with per-phase throughput
//!   attribution reporting the spike.

use optikv::adapt::round_trips;
use optikv::client::consistency::{ClientTiming, ConsistencyCfg};
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios::{self, AdaptRun};
use optikv::faults::plan::{FaultEvent, FaultPlan};
use optikv::sim::{Time, SEC};
use optikv::workload::churn::{ChurnEvent, ChurnPlan};
use optikv::workload::keyspace::KeyDist;
use optikv::workload::WorkloadCfg;

/// Kvmix on the 3-zone regional cluster: client 2 leaves at 10 s and
/// rejoins at 20 s; server 1 crashes at 12 s for 5 s. Leave/rejoin and
/// crash/restart ride one merged timeline.
fn churn_under_crash() -> ExpConfig {
    let mut cfg = ExpConfig::new("wl-churn-crash", ConsistencyCfg::n3r1w1(), AppKind::KvMix)
        .with_fault_plan(FaultPlan::none().with(FaultEvent::Crash {
            server: 1,
            at: 12 * SEC,
            restart_after: 5 * SEC,
        }));
    cfg.n_clients = 8;
    cfg.monitors = true;
    cfg.duration = 40 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.timing = ClientTiming::with_think(2.5);
    let wl = WorkloadCfg::uniform_default()
        .with_keys(32, 4)
        .with_dist(KeyDist::Zipf { theta: 0.99 })
        .with_churn(ChurnPlan::none().with(ChurnEvent {
            client: 2,
            at: 10 * SEC,
            rejoin_after: 10 * SEC,
        }));
    cfg.with_workload(wl)
}

/// Sum of one client's per-window op counts over `[from, until)` sim
/// seconds (window indices derived from the hub's window size).
fn ops_between(res: &ExpResult, client: usize, from: Time, until: Time) -> u64 {
    let m = res.metrics.borrow();
    let (a, b) = ((from / m.window) as usize, (until / m.window) as usize);
    let series = m.client_window_ops(client);
    series.iter().take(b.min(series.len())).skip(a).sum()
}

#[test]
fn departed_client_goes_dark_and_rejoins() {
    let res = run(&churn_under_crash());

    // the composition actually ran: app progress, a crossed cut, and
    // both lifecycle arcs (client rejoin + server recovery)
    assert!(res.ops_ok > 200, "progress under churn+crash: {}", res.ops_ok);
    assert_eq!(res.rejoins, 1, "exactly one client rejoin");
    assert!(res.crashes >= 1, "the server crash was delivered");
    assert!(res.sim_stats.fault_dropped > 0, "in-flight messages hit a dead proc");

    // client 2's windows: busy before the leave, dark while gone,
    // busy again after the rejoin (skip a boundary window on each
    // edge of the gap for in-flight straddlers)
    assert!(ops_between(&res, 2, SEC, 10 * SEC) > 0, "active before leaving");
    assert_eq!(
        ops_between(&res, 2, 11 * SEC, 20 * SEC),
        0,
        "no ops complete while the client is gone"
    );
    assert!(ops_between(&res, 2, 21 * SEC, 40 * SEC) > 0, "active again after rejoining");

    // an undisturbed client never goes dark mid-run
    assert!(ops_between(&res, 3, 11 * SEC, 20 * SEC) > 0, "other clients keep running");
}

#[test]
fn churn_under_crash_is_bit_identical_threaded() {
    let serial = run(&churn_under_crash());
    let threaded = run(&churn_under_crash().with_shards(2).with_threaded());
    assert_eq!(serial.sim_stats.events, threaded.sim_stats.events);
    assert_eq!(serial.ops_ok, threaded.ops_ok);
    assert_eq!(serial.rejoins, threaded.rejoins);
    assert_eq!(serial.violations_detected, threaded.violations_detected);
    assert_eq!(serial.app_tps.to_bits(), threaded.app_tps.to_bits());
    assert_eq!(
        serial.metrics.borrow().key_ops(),
        threaded.metrics.borrow().key_ops(),
        "per-key traffic merges back to the serial counts"
    );
}

#[test]
fn violation_rate_is_monotone_in_skew() {
    // the sweep endpoints: uniform traffic vs heavy skew. Heavier skew
    // concentrates guarded writes on fewer hot keys, so the per-kop
    // violation rate must rise (the CLI smoke gate checks the full
    // sweep; this pins the endpoints in `cargo test`).
    let uniform = run(&scenarios::kvmix_skew(0.0, AdaptRun::StaticEventual, 0.05, 42));
    let skewed = run(&scenarios::kvmix_skew(1.2, AdaptRun::StaticEventual, 0.05, 42));
    assert!(uniform.ops_ok > 100 && skewed.ops_ok > 100);
    assert!(
        skewed.violations_per_kop > uniform.violations_per_kop,
        "zipf 1.2 must out-violate uniform: {} vs {}",
        skewed.violations_per_kop,
        uniform.violations_per_kop
    );
    // and the contention stats agree on where the traffic went
    assert!(skewed.hot_key_share > uniform.hot_key_share);
    assert!(skewed.keys_p90 < uniform.keys_p90);
}

#[test]
fn flash_crowd_under_partition_round_trips_the_controller() {
    let res = run(&scenarios::kvmix_flash_crowd(AdaptRun::Adaptive, true, 0.1, 42));
    assert!(
        round_trips(&res.mode_timeline) >= 1,
        "escalate + release expected under the partitioned flash crowd: {:?}",
        res.mode_timeline
    );
    assert!(res.mode_timeline.last().unwrap().cfg.is_eventual(), "ends optimistic");

    // per-phase attribution sees the spike: the crowd phase carries
    // more throughput than the pre-crowd baseline
    let tps_of = |label: &str| -> f64 {
        res.phase_tps
            .iter()
            .find(|(l, _)| l.as_str() == label)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("phase {label} missing: {:?}", res.phase_tps))
    };
    assert_eq!(res.phase_tps.len(), 3, "flat/flat/flat flash-crowd shape: {:?}", res.phase_tps);
    assert!(
        tps_of("1:flat") > tps_of("0:flat"),
        "crowd phase outpaces baseline: {:?}",
        res.phase_tps
    );
}
