//! End-to-end rollback tests: the FullRestore recovery path (freeze →
//! window-log/snapshot restore → resume) and the NotifyClients task
//! abort-restart path, both triggered by real detected violations.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::run;
use optikv::rollback::recovery::RecoveryPolicy;
use optikv::sim::SEC;

fn violating_cfg(recovery: RecoveryPolicy, seed: u64) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        "rollback-e2e",
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 5, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
    );
    cfg.n_clients = 6;
    cfg.duration = 40 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.recovery = recovery;
    cfg.seed = seed;
    cfg
}

#[test]
fn full_restore_recovers_and_system_continues() {
    let res = run(&violating_cfg(RecoveryPolicy::FullRestore, 51));
    assert!(res.violations_detected > 0, "violations occur");
    assert!(res.recoveries > 0, "controller ran recoveries");
    // the system keeps making progress after stop-the-world restores
    assert!(res.ops_ok > 200, "ops_ok={}", res.ops_ok);
    // rate limiting: recoveries are far fewer than violations
    assert!(res.recoveries as usize <= res.violations_detected);
}

#[test]
fn full_restore_recovers_on_pipelined_clients() {
    // depth 4: overlapped ops go stale wholesale when the controller
    // freezes/restores; the system must keep recovering and progressing
    let res = run(&violating_cfg(RecoveryPolicy::FullRestore, 51).with_pipeline_depth(4));
    assert!(res.violations_detected > 0, "violations occur");
    assert!(res.recoveries > 0, "controller ran recoveries");
    assert!(res.ops_ok > 200, "ops_ok={}", res.ops_ok);
}

#[test]
fn notify_clients_is_cheaper_than_full_restore() {
    let notify = run(&violating_cfg(RecoveryPolicy::NotifyClients, 53));
    let full = run(&violating_cfg(RecoveryPolicy::FullRestore, 53));
    assert!(notify.ops_ok > 0 && full.ops_ok > 0);
    // freeze/restore pauses every server; client-side restart does not
    assert!(
        notify.app_tps >= full.app_tps * 0.95,
        "notify ({:.0}) should not lose to full restore ({:.0})",
        notify.app_tps,
        full.app_tps
    );
}

#[test]
fn crash_during_freeze_cannot_wedge_full_restore() {
    use optikv::faults::{FaultEvent, FaultPlan};
    // server 1 is down for most of the run, so any freeze broadcast in
    // that window can never collect its ack — exactly the shape that
    // used to wedge the controller in `Freezing` forever (PR-3 notes)
    let cfg = violating_cfg(RecoveryPolicy::FullRestore, 59).with_fault_plan(
        FaultPlan::none().with(FaultEvent::Crash {
            server: 1,
            at: 5 * SEC,
            restart_after: 25 * SEC,
        }),
    );
    let res = run(&cfg);
    assert!(res.violations_detected > 0, "violations occur");
    assert_eq!(res.crashes, 1, "the crash fired");
    assert!(res.recoveries > 0, "recoveries started despite the crash");
    // the deadline decides on the live majority, so restores complete
    assert!(res.completed_recoveries > 0, "no recovery may wedge");
    // and at least one ack phase actually hit its deadline
    assert!(res.recovery_ack_timeouts >= 1, "deadline path exercised");
    // the cluster keeps making progress through and after the window
    assert!(res.ops_ok > 200, "ops_ok={}", res.ops_ok);
}

#[test]
fn reset_to_clean_recovers_through_a_crash() {
    use optikv::faults::{FaultEvent, FaultPlan};
    let cfg = violating_cfg(RecoveryPolicy::ResetToClean, 61).with_fault_plan(
        FaultPlan::none().with(FaultEvent::Crash {
            server: 1,
            at: 5 * SEC,
            restart_after: 25 * SEC,
        }),
    );
    let res = run(&cfg);
    assert!(res.violations_detected > 0, "violations occur");
    assert_eq!(res.crashes, 1, "the crash fired");
    assert!(res.recoveries > 0, "recoveries started");
    // no freeze phase exists to wedge; the rolling reset must terminate
    // even when the crashed server never acks (skipped at its deadline)
    assert!(res.completed_recoveries > 0, "no recovery may wedge");
    assert!(res.resets > 0, "servers actually dropped and re-derived state");
    assert!(res.resyncs > 0, "re-derivation used the peer sync path");
    assert!(res.ops_ok > 200, "ops_ok={}", res.ops_ok);
}

#[test]
fn stabilize_records_violations_and_never_stalls() {
    let res = run(&violating_cfg(RecoveryPolicy::Stabilize, 63));
    assert!(res.violations_detected > 0, "violations occur");
    assert!(res.recoveries > 0, "the controller still tracks recoveries");
    assert_eq!(res.completed_recoveries, res.recoveries, "every one completes instantly");
    assert_eq!(res.recovery_ack_timeouts, 0, "no ack phases exist to time out");
    assert_eq!(res.mean_recovery_ms, 0.0, "time-to-recover is zero by construction");
    assert!(res.ops_ok > 200, "ops_ok={}", res.ops_ok);
}

#[test]
fn stabilizing_coloring_converges_without_aborts() {
    // the Stabilize strategy's demonstration workload: violations are
    // recorded, nothing rolls back, no task aborts — and the app keeps
    // completing tasks through a crash/restart cycle
    let res = run(&optikv::exp::scenarios::stabilize_coloring(0.15, 65));
    assert!(res.metrics.borrow().tasks_completed > 0, "the pass keeps completing");
    assert_eq!(res.metrics.borrow().tasks_aborted, 0, "stabilize never aborts a task");
    assert_eq!(res.restarts, 0, "no client restarts either");
    assert!(res.ops_ok > 500);
}

#[test]
fn recovery_none_just_records() {
    let res = run(&violating_cfg(RecoveryPolicy::None, 55));
    assert!(res.violations_detected > 0);
    assert_eq!(res.recoveries, 0);
}

#[test]
fn coloring_task_restart_on_violation() {
    // eventual consistency + tight contention: aborted tasks restart and
    // the run still completes tasks
    let mut cfg = ExpConfig::new(
        "rollback-coloring",
        ConsistencyCfg::n3r1w1(),
        AppKind::Coloring { nodes: 150, edges_per_node: 3, task_size: 5, loop_forever: true },
    );
    cfg.n_clients = 6;
    cfg.duration = 90 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.recovery = RecoveryPolicy::NotifyClients;
    cfg.seed = 57;
    let res = run(&cfg);
    assert!(res.metrics.borrow().tasks_completed > 0);
    assert!(res.ops_ok > 500);
    // if violations were detected, restarts must have happened
    if res.violations_detected > 0 {
        assert!(res.restarts > 0, "violations must trigger task restarts");
    }
}
