//! Skew sweep — the kvmix production-traffic workload across Zipf
//! parameters θ ∈ {0, 0.8, 0.99, 1.2}, each under the two static
//! consistency pins and the adaptive hysteresis controller
//! (`scenarios::kvmix_skew`).
//!
//! The claims under test: the per-kop violation rate is monotone in θ
//! (heavier skew concentrates guarded writes onto fewer hot keys), and
//! the adaptive run tracks the cheaper static pin at light skew while
//! escalating under heavy skew — the PCAP-style tradeoff the workload
//! engine exists to expose. Per row we report app throughput, the
//! contention stats (hot-key share, ranks covering 90 % of traffic),
//! violations per kop, detection p99.9 and mode switches.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench skew_sweep` for paper-length runs.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{kvmix_skew, AdaptRun, SKEW_THETAS};
use optikv::metrics::report::{bench_scale, bench_seed, benefit_pct};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# kvmix skew sweep: violation rate & adaptive benefit vs θ (scale {scale})\n");

    let mut t = Table::new(&[
        "theta",
        "run",
        "app ops/s",
        "viol/kop",
        "hot-key share",
        "keys@90%",
        "detect p99.9 ms",
        "switches",
    ]);
    let mut static_rates: Vec<f64> = Vec::new();
    let mut adaptive_vs_best: Vec<(f64, f64)> = Vec::new();
    let kinds = [AdaptRun::StaticEventual, AdaptRun::StaticSequential, AdaptRun::Adaptive];
    for &theta in &SKEW_THETAS {
        let mut tps = [0.0f64; 3];
        for (i, kind) in kinds.into_iter().enumerate() {
            let res = run(&kvmix_skew(theta, kind, scale, seed));
            tps[i] = res.app_tps;
            if kind == AdaptRun::StaticEventual {
                static_rates.push(res.violations_per_kop);
            }
            t.row(&[
                theta.to_string(),
                kind.label().to_string(),
                format!("{:.1}", res.app_tps),
                format!("{:.2}", res.violations_per_kop),
                format!("{:.3}", res.hot_key_share),
                res.keys_p90.to_string(),
                format!("{:.2}", res.detection_cdf.quantile(0.999)),
                res.mode_switches.to_string(),
            ]);
        }
        adaptive_vs_best.push((theta, benefit_pct(tps[2], tps[0].max(tps[1]))));
    }
    println!("{}", t.render());

    let monotone = static_rates.windows(2).all(|w| w[1] >= w[0]);
    println!(
        "eventual-pin viol/kop across θ: {:?} | monotone: {}",
        static_rates.iter().map(|v| format!("{v:.2}")).collect::<Vec<_>>(),
        monotone
    );
    for (theta, pct) in &adaptive_vs_best {
        println!("theta {theta}: adaptive vs best static {pct:+.1}%");
    }
}
