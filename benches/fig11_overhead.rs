//! Fig. 11 — Overhead of the monitoring module on each consistency model,
//! Social Media Analysis, AWS 3-region, N=3, 15 clients. Overhead is
//! measured at the *server* perspective (monitors interfere with server
//! CPU) by comparing runs with the monitors enabled and disabled.
//! Paper: 1–2% with up to ~20 000 active predicates.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench fig11_overhead` for paper scale.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{social_media_aws, table2_n3};
use optikv::rollback::recovery::RecoveryPolicy;
use optikv::metrics::report::{bench_scale, bench_seed, overhead_pct};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.01);
    let seed = bench_seed();
    println!("# Fig. 11 — monitoring overhead per consistency model (scale {scale})\n");

    let mut t = Table::new(&[
        "model",
        "server ops/s (mon ON)",
        "server ops/s (mon OFF)",
        "overhead",
        "peak active preds",
        "paper",
    ]);
    for c in table2_n3() {
        // recovery disabled on both sides: overhead must compare identical
        // workloads (the monitors-as-debugger deployment, §IV)
        let mut cfg_on = social_media_aws(c, true, scale, seed);
        cfg_on.recovery = RecoveryPolicy::None;
        let mut cfg_off = social_media_aws(c, false, scale, seed);
        cfg_off.recovery = RecoveryPolicy::None;
        let on = run(&cfg_on);
        let off = run(&cfg_off);
        let ov = overhead_pct(on.server_tps, off.server_tps);
        t.row(&[
            c.label(),
            format!("{:.1}", on.server_tps),
            format!("{:.1}", off.server_tps),
            format!("{ov:.2}%"),
            on.active_preds_peak.to_string(),
            "1–2%".into(),
        ]);
        assert!(ov < 8.5, "overhead {ov:.1}% on {} exceeds the paper's worst case", c.label());
    }
    println!("{}", t.render());
    println!("# PASS (all overheads within the paper's ≤8% envelope; typical ≤4%)");
}
