//! Fault recovery — throughput dip and recovery time around a network
//! partition heal, plus the crash-churn re-sync cost.
//!
//! The partition study runs `scenarios::partition_coloring`: the AWS
//! global topology with region 2 cut off for the middle third of the
//! run. We report the stable application throughput before the cut,
//! during it, and after the heal, and the recovery time — how many
//! 1-second windows after the heal it takes the aggregate to climb back
//! to 90 % of the pre-cut mean.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench fault_recovery` for long runs.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{crash_churn_conjunctive, partition_coloring};
use optikv::metrics::report::{bench_scale, bench_seed, detection_cdf_summary};
use optikv::sim::SEC;
use optikv::util::stats::{mean, Table};

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# fault recovery — partition dip/heal and crash-churn re-sync (scale {scale})\n");

    let cfg = partition_coloring(scale, seed);
    let d_secs = (cfg.duration / SEC) as usize;
    let (cut_from, cut_until) = (d_secs / 3, 2 * d_secs / 3);
    let res = run(&cfg);
    let series = res.metrics.borrow().app_series();

    // window the series around the partition (skip the warmup quarter of
    // the pre-cut phase and the final, possibly partial, window)
    let len = series.len();
    let slice = |a: usize, b: usize| -> Vec<f64> {
        let (a, b) = (a.min(len), b.min(len));
        series[a..b.max(a)].to_vec()
    };
    let pre = slice(cut_from / 4, cut_from);
    let during = slice(cut_from, cut_until);
    let post = slice(cut_until, len.saturating_sub(1));
    let (pre_tps, during_tps, post_tps) = (mean(&pre), mean(&during), mean(&post));
    let recovery_s = post
        .iter()
        .position(|&x| x >= 0.9 * pre_tps)
        .map(|w| format!("{w} s"))
        .unwrap_or_else(|| "not within run".into());

    let mut t = Table::new(&["phase", "windows", "app ops/s", "vs pre-cut"]);
    let pct = |x: f64| {
        if pre_tps > 0.0 {
            format!("{:+.1}%", (x - pre_tps) / pre_tps * 100.0)
        } else {
            "—".into()
        }
    };
    t.row(&["pre-cut".into(), pre.len().to_string(), format!("{pre_tps:.1}"), "—".into()]);
    t.row(&[
        "partitioned".into(),
        during.len().to_string(),
        format!("{during_tps:.1}"),
        pct(during_tps),
    ]);
    t.row(&["healed".into(), post.len().to_string(), format!("{post_tps:.1}"), pct(post_tps)]);
    println!("{}", t.render());
    println!(
        "recovery to 90% of pre-cut throughput: {recovery_s} after heal | \
         failed ops {} | msgs cut {} | violations {}",
        res.ops_failed, res.sim_stats.fault_dropped, res.violations_detected
    );
    print!("{}", detection_cdf_summary(&res.detection_cdf));

    println!("\n# crash churn — volatile-state loss and peer re-sync\n");
    let res = run(&crash_churn_conjunctive(scale, seed));
    println!(
        "{}: app {:.1} ops/s | crashes {} | re-syncs {} | versions merged {} | violations {}",
        res.name,
        res.app_tps,
        res.crashes,
        res.resyncs,
        res.resync_keys,
        res.violations_detected
    );
}
