//! Fig. 12 — Impact of workload characteristics (Weather Monitoring,
//! single AWS region with 5 AZs, N=5, 10 clients): benefit of eventual
//! consistency + monitoring over the sequential configurations, and
//! monitoring overhead, at PUT% ∈ {25, 50}.
//!
//! Paper shapes: benefit over N5R1W5 grows 18% → 37% as PUT% rises
//! (writes are expensive at W=5); balanced N5R3W3 overtakes N5R1W5 at
//! high PUT%; overhead ≤ 4%.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench fig12_weather_workload` for paper scale.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::weather_regional;
use optikv::metrics::report::{bench_scale, bench_seed, benefit_pct, overhead_pct};
use optikv::rollback::recovery::RecoveryPolicy;
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# Fig. 12 — weather monitoring benefit & overhead vs PUT% (scale {scale})\n");

    let mut benefit_15 = Vec::new();
    let mut t = Table::new(&[
        "PUT%",
        "N5R1W1+mon",
        "N5R1W5",
        "benefit",
        "N5R3W3",
        "benefit",
        "overhead",
    ]);
    for put_pct in [0.25, 0.5] {
        let mut cfg_on = weather_regional(ConsistencyCfg::n5r1w1(), true, put_pct, scale, seed);
        cfg_on.recovery = RecoveryPolicy::None;
        let mut cfg_off = weather_regional(ConsistencyCfg::n5r1w1(), false, put_pct, scale, seed);
        cfg_off.recovery = RecoveryPolicy::None;
        let ev = run(&cfg_on);
        let ev_off = run(&cfg_off);
        let s15 = run(&weather_regional(ConsistencyCfg::n5r1w5(), false, put_pct, scale, seed));
        let s33 = run(&weather_regional(ConsistencyCfg::n5r3w3(), false, put_pct, scale, seed));
        let b15 = benefit_pct(ev.app_tps, s15.app_tps);
        benefit_15.push(b15);
        let ov = overhead_pct(ev.server_tps, ev_off.server_tps);
        t.row(&[
            format!("{:.0}%", put_pct * 100.0),
            format!("{:.1}", ev.app_tps),
            format!("{:.1}", s15.app_tps),
            format!("+{b15:.0}%"),
            format!("{:.1}", s33.app_tps),
            format!("+{:.0}%", benefit_pct(ev.app_tps, s33.app_tps)),
            format!("{ov:.2}%"),
        ]);
        assert!(ev.app_tps > s15.app_tps, "eventual must beat N5R1W5 at PUT%={put_pct}");
        assert!(ov < 8.5, "overhead {ov:.1}% out of envelope");
    }
    println!("{}", t.render());
    println!(
        "# shape check: benefit over N5R1W5 grows with PUT% ({:.0}% → {:.0}%; paper 18% → 37%)",
        benefit_15[0], benefit_15[1]
    );
    assert!(
        benefit_15[1] > benefit_15[0],
        "benefit must grow with PUT% (writes cost W=5 more)"
    );
    println!("# PASS");
}
