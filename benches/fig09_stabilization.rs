//! Fig. 9 — Result stabilization: the Social Media Analysis application
//! run three times (different seeds) with monitoring enabled; per-window
//! aggregated application throughput converges to a stable value after an
//! initialization phase. Prints the three series and their average, plus
//! the stable-phase mean each run converges to.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench fig09_stabilization` for paper scale.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::social_media_aws;
use optikv::metrics::report::{bench_scale, bench_seed};
use optikv::metrics::throughput::stable_mean;
use optikv::util::stats::{cv, Table};

fn main() {
    let scale = bench_scale(0.01);
    println!("# Fig. 9 — result stabilization (scale {scale})");
    println!("# coloring on AWS-global, N=3, C/N=5, monitors ON, 3 runs\n");

    let mut serieses = Vec::new();
    for run_idx in 0..3u64 {
        let cfg = social_media_aws(ConsistencyCfg::n3r1w1(), true, scale, bench_seed() + run_idx);
        let res = run(&cfg);
        let series = res.metrics.borrow().app_series();
        serieses.push(series);
    }
    let len = serieses.iter().map(|s| s.len()).min().unwrap_or(0);
    let mut t = Table::new(&["t (s)", "run 1", "run 2", "run 3", "average"]);
    for w in 0..len {
        let vals: Vec<f64> = serieses.iter().map(|s| s[w]).collect();
        let avg = vals.iter().sum::<f64>() / vals.len() as f64;
        t.row(&[
            w.to_string(),
            format!("{:.1}", vals[0]),
            format!("{:.1}", vals[1]),
            format!("{:.1}", vals[2]),
            format!("{:.1}", avg),
        ]);
    }
    println!("{}", t.render());

    for (i, s) in serieses.iter().enumerate() {
        let sm = stable_mean(s, 0.25);
        let stable_cv = if s.len() > 4 { cv(&s[s.len() / 4..s.len() - 1]) } else { 0.0 };
        println!(
            "run {}: stable mean {:.1} ops/s, stable-phase CV {:.3} (convergence ⇔ small CV)",
            i + 1,
            sm,
            stable_cv
        );
    }
    println!("\n# paper: every run converges to a stable value after a short initialization;");
    println!("# with global-network latencies (~114 ms avg RTT) and 15 closed-loop clients the");
    println!("# expected aggregate is ≈ 15/0.117 ≈ 128 ops/s at full scale.");
}
