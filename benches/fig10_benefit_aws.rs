//! Fig. 10 — Benefit of eventual consistency with monitors vs sequential
//! consistency without monitors, Social Media Analysis on AWS (3 regions,
//! N=3, 15 clients). Paper: +57% over N3R1W3 and +78% over N3R2W2.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench fig10_benefit_aws` for paper scale.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::social_media_aws;
use optikv::metrics::report::{bench_scale, bench_seed, benefit_pct};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.01);
    let seed = bench_seed();
    println!("# Fig. 10 — benefit of N3R1W1+monitors vs sequential (scale {scale})\n");

    let ev = run(&social_media_aws(ConsistencyCfg::n3r1w1(), true, scale, seed));
    let r1w3 = run(&social_media_aws(ConsistencyCfg::n3r1w3(), false, scale, seed));
    let r2w2 = run(&social_media_aws(ConsistencyCfg::n3r2w2(), false, scale, seed));

    let mut t = Table::new(&["configuration", "app throughput (ops/s)", "benefit of eventual+mon", "paper"]);
    t.row(&["N3R1W1 + monitors".into(), format!("{:.1}", ev.app_tps), "—".into(), "—".into()]);
    t.row(&[
        "N3R1W3 (sequential)".into(),
        format!("{:.1}", r1w3.app_tps),
        format!("+{:.0}%", benefit_pct(ev.app_tps, r1w3.app_tps)),
        "+57%".into(),
    ]);
    t.row(&[
        "N3R2W2 (sequential)".into(),
        format!("{:.1}", r2w2.app_tps),
        format!("+{:.0}%", benefit_pct(ev.app_tps, r2w2.app_tps)),
        "+78%".into(),
    ]);
    println!("{}", t.render());
    println!("# shape checks: N3R1W1+mon wins both; GET-dominated workload ⇒ R1W3 > R2W2");
    assert!(ev.app_tps > r1w3.app_tps && ev.app_tps > r2w2.app_tps, "eventual must win");
    assert!(r1w3.app_tps > r2w2.app_tps, "GET-heavy: R=1 beats R=2");
    println!("# PASS");
}
