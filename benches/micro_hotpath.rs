//! Micro/ablation benches of the hot paths (wall-clock, not virtual time):
//!
//! * HVC compare and the 3-case interval verdict (the innermost op);
//! * native vs XLA(PJRT/Pallas) verdict backends across batch sizes —
//!   the dispatch-overhead crossover the DESIGN.md ablation calls for;
//! * local-detector PUT interception (relevant vs irrelevant keys);
//! * monitor candidate processing;
//! * DES event throughput (events/s of the full simulator).
//!
//! Plain `harness = false` main (criterion is unavailable offline).

use std::time::Instant;

use optikv::clock::hvc::{Hvc, HvcInterval, IntervalOrd, Millis, EPS_INF};
use optikv::runtime::accel::{Accel, NativeAccel, PairQuery};
use optikv::util::rng::Rng;
use optikv::util::stats::Table;

/// ns/pair on the XLA backend, when compiled in and artifacts exist.
#[cfg(feature = "accel")]
fn xla_ns_per_pair(pairs: &[PairQuery<'_>], batch: usize) -> Option<f64> {
    use optikv::runtime::pjrt::XlaAccel;
    let mut x = XlaAccel::load(&XlaAccel::default_dir()).ok()?;
    // warm up the executable once
    let _ = x.pair_verdicts(pairs, 10);
    let xi = (2_000 / batch).max(3) as u64;
    Some(time_it(xi, || {
        std::hint::black_box(x.pair_verdicts(pairs, 10));
    }) / batch as f64)
}

#[cfg(not(feature = "accel"))]
fn xla_ns_per_pair(_pairs: &[PairQuery<'_>], _batch: usize) -> Option<f64> {
    None
}

fn time_it<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn random_interval(rng: &mut Rng, d: usize) -> HvcInterval {
    let owner = rng.below(d as u64) as u16;
    let base = rng.range(0, 100_000) as i64;
    let mut sv: Vec<Millis> = (0..d).map(|_| base + rng.range(0, 40) as i64).collect();
    sv[owner as usize] = *sv.iter().max().unwrap();
    let mut ev = sv.clone();
    for x in &mut ev {
        *x += rng.range(0, 60) as i64;
    }
    ev[owner as usize] = *ev.iter().max().unwrap();
    HvcInterval::new(Hvc { owner, v: sv }, Hvc { owner, v: ev })
}

fn main() {
    let mut rng = Rng::new(1);

    println!("# micro_hotpath — wall-clock timings\n");

    // ---- innermost ops ---------------------------------------------------
    let a = random_interval(&mut rng, 5);
    let b = random_interval(&mut rng, 5);
    let t_cmp = time_it(2_000_000, || {
        std::hint::black_box(a.start.compare(&b.start));
    });
    let t_verdict = time_it(2_000_000, || {
        std::hint::black_box(HvcInterval::verdict(&a, &b, 10));
    });
    println!("hvc_compare(d=5):        {:>9.1} ns", t_cmp * 1e9);
    println!("interval_verdict(d=5):   {:>9.1} ns", t_verdict * 1e9);

    // ---- backend crossover ------------------------------------------------
    let mut saw_xla = false;
    let mut t = Table::new(&["batch", "native ns/pair", "xla ns/pair", "xla/native"]);
    for &batch in &[1usize, 8, 64, 256, 1024, 4096] {
        let ivs: Vec<(HvcInterval, HvcInterval)> = (0..batch)
            .map(|_| (random_interval(&mut rng, 5), random_interval(&mut rng, 5)))
            .collect();
        let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
        let mut native = NativeAccel::new();
        let iters = (200_000 / batch).max(10) as u64;
        let tn = time_it(iters, || {
            std::hint::black_box(native.pair_verdicts(&pairs, 10));
        }) / batch as f64;
        let tx = xla_ns_per_pair(&pairs, batch);
        saw_xla |= tx.is_some();
        t.row(&[
            batch.to_string(),
            format!("{:.1}", tn * 1e9),
            tx.map(|v| format!("{:.1}", v * 1e9)).unwrap_or_else(|| "n/a".into()),
            tx.map(|v| format!("{:.1}x", v / tn)).unwrap_or_else(|| "n/a".into()),
        ]);
    }
    println!("\n{}", t.render());
    if !saw_xla {
        println!("(xla columns unavailable: build with --features accel and run `make artifacts`)");
    }

    // ---- eps sweep (verdict mix) ------------------------------------------
    let ivs: Vec<(HvcInterval, HvcInterval)> = (0..4096)
        .map(|_| (random_interval(&mut rng, 5), random_interval(&mut rng, 5)))
        .collect();
    let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
    let mut native = NativeAccel::new();
    for eps in [0i64, 10, 1_000, EPS_INF] {
        let verdicts = native.pair_verdicts(&pairs, eps);
        let conc = verdicts.iter().filter(|&&v| v == IntervalOrd::Concurrent).count();
        println!(
            "eps={:>12}: {:>5.1}% concurrent of {} pairs",
            if eps == EPS_INF { "inf".to_string() } else { eps.to_string() },
            conc as f64 / verdicts.len() as f64 * 100.0,
            verdicts.len()
        );
    }

    // ---- DES event rate -----------------------------------------------------
    use optikv::client::consistency::ConsistencyCfg;
    use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
    let mut cfg = ExpConfig::new(
        "micro-des",
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 6, n_conjuncts: 4, beta: 0.05, put_pct: 0.5 },
    );
    cfg.n_clients = 8;
    cfg.duration = 30 * optikv::sim::SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    let t0 = Instant::now();
    let res = optikv::exp::runner::run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nDES: {} events in {:.2} s wall = {:.0} events/s ({}x faster than real time)",
        res.sim_stats.events,
        wall,
        res.sim_stats.events as f64 / wall,
        (30.0 / wall) as u64
    );
}
