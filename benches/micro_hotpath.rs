//! Micro/ablation benches of the hot paths (wall-clock, not virtual time):
//!
//! * HVC compare and the 3-case interval verdict (the innermost op);
//! * inline vs heap-spilled `HvcVec` representations (clone + tick);
//! * native vs XLA(PJRT/Pallas) verdict backends across batch sizes —
//!   the dispatch-overhead crossover the DESIGN.md ablation calls for;
//! * local-detector PUT interception (relevant vs irrelevant keys);
//! * monitor candidate processing;
//! * DES event throughput (events/s of the full simulator);
//! * binary-heap vs calendar-queue scheduler under the classic hold
//!   model (pop-min + push-successor at steady-state occupancy);
//! * the threaded sharded engine's scaling sweep: the full
//!   `scaleout-s24` production stack at shards ∈ {1,2,4,8}, digest-
//!   checked against the serial engine.
//!
//! Plain `harness = false` main (criterion is unavailable offline).
//!
//! ## `perf` mode
//!
//! `cargo bench --bench micro_hotpath -- perf` switches to the perf
//! harness ([`optikv::exp::perfjson`]): it runs the fixed scenario
//! matrix and writes `BENCH_hotpath.json` — the trajectory file every
//! future perf PR is judged against. `--rows serial,faulted` subsets
//! the matrix (CI smoke runs just `serial`); `--out PATH` or
//! `$PERF_OUT` redirects; `$BENCH_SCALE` / `$BENCH_SEED` as usual.

use std::time::Instant;

use optikv::clock::hvc::{set_force_spill, Hvc, HvcInterval, IntervalOrd, Millis, EPS_INF};
use optikv::exp::perfjson;
use optikv::metrics::report;
use optikv::runtime::accel::{Accel, NativeAccel, PairQuery};
use optikv::util::rng::Rng;
use optikv::util::stats::Table;

/// ns/pair on the XLA backend, when compiled in and artifacts exist.
#[cfg(feature = "accel")]
fn xla_ns_per_pair(pairs: &[PairQuery<'_>], batch: usize) -> Option<f64> {
    use optikv::runtime::pjrt::XlaAccel;
    let mut x = XlaAccel::load(&XlaAccel::default_dir()).ok()?;
    // warm up the executable once
    let _ = x.pair_verdicts(pairs, 10);
    let xi = (2_000 / batch).max(3) as u64;
    Some(time_it(xi, || {
        std::hint::black_box(x.pair_verdicts(pairs, 10));
    }) / batch as f64)
}

#[cfg(not(feature = "accel"))]
fn xla_ns_per_pair(_pairs: &[PairQuery<'_>], _batch: usize) -> Option<f64> {
    None
}

fn time_it<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn random_interval(rng: &mut Rng, d: usize) -> HvcInterval {
    let owner = rng.below(d as u64) as u16;
    let base = rng.range(0, 100_000) as i64;
    let mut sv: Vec<Millis> = (0..d).map(|_| base + rng.range(0, 40) as i64).collect();
    sv[owner as usize] = *sv.iter().max().unwrap();
    let mut ev = sv.clone();
    for x in &mut ev {
        *x += rng.range(0, 60) as i64;
    }
    ev[owner as usize] = *ev.iter().max().unwrap();
    HvcInterval::new(Hvc::from_vec(owner, sv), Hvc::from_vec(owner, ev))
}

/// `perf` mode: run the scenario matrix and write `BENCH_hotpath.json`.
fn run_perf(args: &[String]) {
    let scale = report::bench_scale(0.05);
    let seed = report::bench_seed();
    let rows: Vec<&str> = match args.iter().position(|a| a == "--rows") {
        Some(i) => args
            .get(i + 1)
            .expect("--rows needs a comma-separated list")
            .split(',')
            .collect(),
        None => perfjson::MATRIX.to_vec(),
    };
    let out_path = match args.iter().position(|a| a == "--out") {
        Some(i) => args.get(i + 1).expect("--out needs a path").clone(),
        None => std::env::var("PERF_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into()),
    };

    println!("# perf harness — scale {scale}, seed {seed}, rows {rows:?}\n");
    let mut t = Table::new(&[
        "row",
        "events",
        "wall s",
        "events/s",
        "sent bytes",
        "pairs chk/chg",
        "win peak",
        "ops ok",
        "viol",
        "shards",
        "barriers",
        "imbal",
    ]);
    let mut measured = Vec::new();
    for row in rows {
        let r = perfjson::run_row(row, scale, seed);
        t.row(&[
            r.name.clone(),
            r.events.to_string(),
            format!("{:.2}", r.wall_s),
            format!("{:.0}", r.events_per_sec),
            r.sent_bytes_proxy.to_string(),
            format!("{}/{}", r.pairs_checked, r.pairs_charged),
            r.window_peak.to_string(),
            r.ops_ok.to_string(),
            r.violations.to_string(),
            r.shards.to_string(),
            r.barriers.to_string(),
            format!("{:.3}", r.imbalance),
        ]);
        measured.push(r);
    }
    println!("{}", t.render());
    let json = perfjson::to_json(
        &measured,
        scale,
        seed,
        true,
        "measured by `cargo bench --bench micro_hotpath -- perf`",
    );
    perfjson::write_json(std::path::Path::new(&out_path), &json)
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("wrote {out_path}");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "perf") {
        run_perf(&args);
        return;
    }
    let mut rng = Rng::new(1);

    println!("# micro_hotpath — wall-clock timings\n");

    // ---- innermost ops ---------------------------------------------------
    let a = random_interval(&mut rng, 5);
    let b = random_interval(&mut rng, 5);
    let t_cmp = time_it(2_000_000, || {
        std::hint::black_box(a.start.compare(&b.start));
    });
    let t_verdict = time_it(2_000_000, || {
        std::hint::black_box(HvcInterval::verdict(&a, &b, 10));
    });
    println!("hvc_compare(d=5):        {:>9.1} ns", t_cmp * 1e9);
    println!("interval_verdict(d=5):   {:>9.1} ns", t_verdict * 1e9);

    // ---- HvcVec representations ------------------------------------------
    // clone + tick of a dim-5 clock: the per-message cost the inline
    // representation removes (and what a spill adds back at S > 8)
    let h_inline = Hvc::new(0, 5, 1_000, 10);
    set_force_spill(true);
    let h_spill = Hvc::new(0, 5, 1_000, 10);
    set_force_spill(false);
    let t_inline = time_it(2_000_000, || {
        let mut c = h_inline.clone();
        c.tick(1_001, 10);
        std::hint::black_box(&c);
    });
    let t_spill = time_it(2_000_000, || {
        let mut c = h_spill.clone();
        c.tick(1_001, 10);
        std::hint::black_box(&c);
    });
    println!("hvc_clone+tick inline:   {:>9.1} ns", t_inline * 1e9);
    println!(
        "hvc_clone+tick spilled:  {:>9.1} ns ({:.1}x)",
        t_spill * 1e9,
        t_spill / t_inline
    );

    // ---- backend crossover ------------------------------------------------
    let mut saw_xla = false;
    let mut t = Table::new(&["batch", "native ns/pair", "xla ns/pair", "xla/native"]);
    for &batch in &[1usize, 8, 64, 256, 1024, 4096] {
        let ivs: Vec<(HvcInterval, HvcInterval)> = (0..batch)
            .map(|_| (random_interval(&mut rng, 5), random_interval(&mut rng, 5)))
            .collect();
        let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
        let mut native = NativeAccel::new();
        let iters = (200_000 / batch).max(10) as u64;
        let tn = time_it(iters, || {
            std::hint::black_box(native.pair_verdicts(&pairs, 10));
        }) / batch as f64;
        let tx = xla_ns_per_pair(&pairs, batch);
        saw_xla |= tx.is_some();
        t.row(&[
            batch.to_string(),
            format!("{:.1}", tn * 1e9),
            tx.map(|v| format!("{:.1}", v * 1e9)).unwrap_or_else(|| "n/a".into()),
            tx.map(|v| format!("{:.1}x", v / tn)).unwrap_or_else(|| "n/a".into()),
        ]);
    }
    println!("\n{}", t.render());
    if !saw_xla {
        println!("(xla columns unavailable: build with --features accel and run `make artifacts`)");
    }

    // ---- eps sweep (verdict mix) ------------------------------------------
    let ivs: Vec<(HvcInterval, HvcInterval)> = (0..4096)
        .map(|_| (random_interval(&mut rng, 5), random_interval(&mut rng, 5)))
        .collect();
    let pairs: Vec<PairQuery> = ivs.iter().map(|(a, b)| PairQuery { a, b }).collect();
    let mut native = NativeAccel::new();
    for eps in [0i64, 10, 1_000, EPS_INF] {
        let verdicts = native.pair_verdicts(&pairs, eps);
        let conc = verdicts.iter().filter(|&&v| v == IntervalOrd::Concurrent).count();
        println!(
            "eps={:>12}: {:>5.1}% concurrent of {} pairs",
            if eps == EPS_INF { "inf".to_string() } else { eps.to_string() },
            conc as f64 / verdicts.len() as f64 * 100.0,
            verdicts.len()
        );
    }

    // ---- DES event rate -----------------------------------------------------
    use optikv::client::consistency::ConsistencyCfg;
    use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
    let mut cfg = ExpConfig::new(
        "micro-des",
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 6, n_conjuncts: 4, beta: 0.05, put_pct: 0.5 },
    );
    cfg.n_clients = 8;
    cfg.duration = 30 * optikv::sim::SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    let t0 = Instant::now();
    let res = optikv::exp::runner::run(&cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nDES: {} events in {:.2} s wall = {:.0} events/s ({}x faster than real time)",
        res.sim_stats.events,
        wall,
        res.sim_stats.events as f64 / wall,
        (30.0 / wall) as u64
    );

    // ---- scheduler structures: heap vs calendar (hold model) --------------
    // steady-state pop-min + push-successor at the occupancy a scale-out
    // run actually carries — the shape where the calendar queue's O(1)
    // amortized transfer beats the heap's O(log n) sift
    {
        use optikv::sim::calendar::{CalendarQueue, Keyed};
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;

        #[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
        struct Item {
            at: u64,
            seq: u64,
        }
        impl Keyed for Item {
            fn key(&self) -> (u64, u64) {
                (self.at, self.seq)
            }
        }

        let occupancy = 65_536u64;
        let steps = 2_000_000u64;
        let mut seed_rng = Rng::new(11);
        let init: Vec<(u64, u64)> =
            (0..occupancy).map(|s| (seed_rng.below(1_000_000_000), s)).collect();

        let mut heap: BinaryHeap<Reverse<Item>> =
            init.iter().map(|&(at, seq)| Reverse(Item { at, seq })).collect();
        let mut rng = Rng::new(12);
        let mut seq = occupancy;
        let t_heap = time_it(steps, || {
            let Reverse(it) = heap.pop().unwrap();
            heap.push(Reverse(Item { at: it.at + rng.below(2_000_000) + 1, seq }));
            seq += 1;
        });

        let mut cal: CalendarQueue<Item> = CalendarQueue::new();
        for &(at, seq) in &init {
            cal.push(Item { at, seq });
        }
        let mut rng = Rng::new(12);
        let mut seq = occupancy;
        let t_cal = time_it(steps, || {
            let it = cal.pop().unwrap();
            cal.push(Item { at: it.at + rng.below(2_000_000) + 1, seq });
            seq += 1;
        });
        println!(
            "\nhold model ({} pending): heap {:.1} ns/op, calendar {:.1} ns/op ({:.2}x)",
            occupancy,
            t_heap * 1e9,
            t_cal * 1e9,
            t_heap / t_cal
        );
    }

    // ---- threaded engine: full-stack scaling sweep ------------------------
    {
        use optikv::exp::{runner, scenarios};

        println!("\n# threaded engine — full-stack scaleout (24 servers, monitors on)\n");
        let mk = || scenarios::scaleout_conjunctive(24, 0.05, 7);
        let mut t = Table::new(&[
            "shards", "events", "wall s", "events/s", "speedup", "barriers", "imbal",
        ]);
        let t0 = Instant::now();
        let serial = runner::run(&mk());
        let wall = t0.elapsed().as_secs_f64();
        let base_eps = serial.sim_stats.events as f64 / wall;
        t.row(&[
            "serial".into(),
            serial.sim_stats.events.to_string(),
            format!("{wall:.2}"),
            format!("{base_eps:.0}"),
            "1.00x".into(),
            "-".into(),
            "-".into(),
        ]);
        for shards in [1usize, 2, 4, 8] {
            let t0 = Instant::now();
            let r = runner::run(&mk().with_shards(shards).with_threaded());
            let wall = t0.elapsed().as_secs_f64();
            assert_eq!(
                r.sim_stats.events, serial.sim_stats.events,
                "threaded run diverged from serial at shards={shards}"
            );
            let eps = r.sim_stats.events as f64 / wall;
            t.row(&[
                shards.to_string(),
                r.sim_stats.events.to_string(),
                format!("{wall:.2}"),
                format!("{eps:.0}"),
                format!("{:.2}x", eps / base_eps),
                r.barriers.to_string(),
                format!("{:.3}", perfjson::imbalance(&r.shard_events)),
            ]);
        }
        println!("{}", t.render());
    }
}
