//! Table III — Detection latency distribution over Conjunctive predicate
//! violations (β = 1%, PUT% = 50, 10-conjunct predicates, regional
//! network, both consistency models).
//!
//! Paper: 20 647 violations; 99.927% < 50 ms, 0.029% in 50–1000 ms,
//! 0.015% in 1–10 s, 0.029% in 10–17 s; average 8 ms, max 17 s.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench table3_detection_latency` for paper scale.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::conjunctive_regional;
use optikv::metrics::report::{bench_scale, bench_seed, latency_table};
use optikv::util::stats;

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# Table III — detection latency of conjunctive violations (scale {scale})\n");

    // the paper aggregates violations across runs on both eventual and
    // sequential consistency
    let mut latencies: Vec<f64> = Vec::new();
    for (c, runs) in [
        (ConsistencyCfg::n5r1w1(), 2u64),
        (ConsistencyCfg::n5r1w5(), 1),
        (ConsistencyCfg::n5r3w3(), 1),
    ] {
        for r in 0..runs {
            let res = run(&conjunctive_regional(c, true, scale, seed + r));
            latencies.extend(res.detection_latencies_ms.iter().map(|&l| l.max(0.0)));
        }
    }

    println!("{}", latency_table(&latencies));
    println!("# paper: 99.93% < 50 ms | 0.03% 50–1000 | 0.015% 1–10 s | 0.03% 10–17 s; avg 8 ms");

    assert!(!latencies.is_empty(), "the stress workload must produce violations");
    let under_1s = latencies.iter().filter(|&&l| l < 1_000.0).count() as f64
        / latencies.len() as f64;
    assert!(
        under_1s > 0.99,
        "regional detection must be sub-second for >99% ({:.2}%)",
        under_1s * 100.0
    );
    let p50 = stats::percentile(&latencies, 50.0);
    assert!(p50 < 100.0, "median latency should be tens of ms, got {p50:.1}");
    println!("# PASS ({} violations, {:.3}% < 1 s)", latencies.len(), under_1s * 100.0);
}
