//! Adaptive benefit — the hysteresis controller vs the two static pins
//! on the fault-phased scenario (`scenarios::adaptive_conjunctive`).
//!
//! The run has three phases: healthy, *bad* (region 2 partitioned off,
//! so the eventual mode's W = 2 writes from that region expire), and
//! healed. Per phase we report the aggregate application throughput of
//! each run; the claim under test is that the adaptive run tracks the
//! best static mode in every phase (within the noise of the switch
//! transients), ends with ≥ 1 eventual→sequential→eventual round trip,
//! and lands within 5 % of the best static pin overall.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench adaptive_benefit` for long runs.

use optikv::adapt::round_trips;
use optikv::exp::runner::{run, ExpResult};
use optikv::exp::scenarios::{adaptive_conjunctive, AdaptRun};
use optikv::metrics::report::{bench_scale, bench_seed, benefit_pct, mode_timeline_summary};
use optikv::sim::SEC;
use optikv::util::stats::{mean, Table};

fn main() {
    let scale = bench_scale(0.2);
    let seed = bench_seed();
    println!("# adaptive consistency vs static pins (scale {scale})\n");

    let probe = adaptive_conjunctive(AdaptRun::Adaptive, scale, seed);
    let d_secs = (probe.duration / SEC) as usize;
    // the scenario cuts region 2 off for the middle fifth of the run
    let (cut_from, cut_until) = (2 * d_secs / 5, 3 * d_secs / 5);

    let runs: Vec<(AdaptRun, ExpResult)> =
        [AdaptRun::StaticEventual, AdaptRun::StaticSequential, AdaptRun::Adaptive]
            .into_iter()
            .map(|k| (k, run(&adaptive_conjunctive(k, scale, seed))))
            .collect();

    let phase = |r: &ExpResult, a: usize, b: usize| -> f64 {
        let series = r.metrics.borrow().app_series();
        let (a, b) = (a.min(series.len()), b.min(series.len()));
        mean(&series[a..b.max(a)])
    };

    let mut t = Table::new(&[
        "run",
        "overall ops/s",
        "healthy ops/s",
        "bad-phase ops/s",
        "healed ops/s",
        "timeouts",
        "switches",
    ]);
    for (kind, res) in &runs {
        t.row(&[
            kind.label().to_string(),
            format!("{:.1}", res.app_tps),
            // skip the warmup quarter of the healthy phase
            format!("{:.1}", phase(res, cut_from / 4, cut_from)),
            format!("{:.1}", phase(res, cut_from, cut_until)),
            format!("{:.1}", phase(res, cut_until, d_secs.saturating_sub(1))),
            res.quorum_timeouts.to_string(),
            res.mode_switches.to_string(),
        ]);
    }
    println!("{}", t.render());

    let adaptive = &runs[2].1;
    let best_static = runs[0].1.app_tps.max(runs[1].1.app_tps);
    print!("{}", mode_timeline_summary(adaptive));
    println!(
        "adaptive vs best static overall: {:+.1}% (acceptance: >= -5%) | round trips: {}",
        benefit_pct(adaptive.app_tps, best_static),
        round_trips(&adaptive.mode_timeline),
    );
}
