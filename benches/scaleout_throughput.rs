//! Scale-out curve — aggregate throughput vs cluster size at fixed N = 3
//! (the property the seed architecture could not measure: cluster size was
//! hard-wired to the replication factor). Offered load and monitored
//! keyspace grow with the cluster (5 clients and 2 predicates per server),
//! so ideal scaling is linear in S.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench scaleout_throughput` for long runs.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{scaleout_conjunctive, SCALEOUT_SIZES};
use optikv::metrics::report::{bench_scale, bench_seed};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# scale-out — app/server throughput vs cluster size, N=3R1W1 (scale {scale})\n");

    let mut t = Table::new(&[
        "servers",
        "clients",
        "app ops/s",
        "server ops/s",
        "speedup vs S=3",
        "violations",
    ]);
    let mut base_tps = 0.0f64;
    for &s in &SCALEOUT_SIZES {
        let cfg = scaleout_conjunctive(s, scale, seed);
        let res = run(&cfg);
        if s == SCALEOUT_SIZES[0] {
            base_tps = res.app_tps;
        }
        t.row(&[
            s.to_string(),
            cfg.n_clients.to_string(),
            format!("{:.0}", res.app_tps),
            format!("{:.0}", res.server_tps),
            if base_tps > 0.0 {
                format!("{:.2}x", res.app_tps / base_tps)
            } else {
                "—".into()
            },
            res.violations_detected.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("(per-key quorum fan-out stays at N=3 replicas regardless of cluster size)");
}
