//! Pipeline depth sweep — app throughput and client op latency vs
//! `pipeline_depth` on the scatter-gather coloring workload (thin
//! clients, AWS global, N3R1W1). Depth 1 is the paper's serial
//! closed-loop client; the sweep shows how far scatter-gathering the
//! `deg(v)` neighbor reads (plus one commit wave per task) lifts a
//! latency-bound client. Expected shape: ≥ 2× app throughput at depth 8
//! vs depth 1 on the same seed for the single-client rows.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench pipeline_throughput` for long runs.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{pipeline_coloring, PIPELINE_DEPTHS};
use optikv::metrics::report::{bench_scale, bench_seed};
use optikv::util::stats::Table;

fn sweep(n_clients: usize, scale: f64, seed: u64) {
    println!("## {n_clients} client(s)\n");
    let mut t = Table::new(&[
        "depth",
        "app ops/s",
        "speedup vs d=1",
        "op p50 (ms)",
        "op p99 (ms)",
        "tasks done",
        "ok",
    ]);
    let mut base_tps = 0.0f64;
    for &d in &PIPELINE_DEPTHS {
        let cfg = pipeline_coloring(d, n_clients, scale, seed);
        let res = run(&cfg);
        if d == PIPELINE_DEPTHS[0] {
            base_tps = res.app_tps;
        }
        let tasks = res.metrics.borrow().tasks_completed;
        t.row(&[
            d.to_string(),
            format!("{:.0}", res.app_tps),
            if base_tps > 0.0 {
                format!("{:.2}x", res.app_tps / base_tps)
            } else {
                "—".into()
            },
            format!("{:.1}", res.lat_p50_ms),
            format!("{:.1}", res.lat_p99_ms),
            tasks.to_string(),
            res.ops_ok.to_string(),
        ]);
    }
    println!("{}", t.render());
}

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!("# client pipeline — throughput/latency vs depth, coloring N3R1W1 (scale {scale})\n");
    // single client: the pure per-client pipeline win (no lock contention)
    sweep(1, scale, seed);
    // a few clients: cross-client Peterson locks stay sequential, so the
    // win shrinks toward the lock-bound floor — the honest middle ground
    sweep(4, scale, seed);
    println!("(quorum fan-out per op is unchanged; only op overlap varies with depth)");
}
