//! Table IV — Overhead and benefit of the monitors on the local-lab proxy
//! network (Fig. 7/8): one-way inter-region latency ∈ {50, 100} ms,
//! applications {Conjunctive, Weather Monitoring, Social Media Analysis},
//! consistency models N3R1W1 / N3R2W2 / N3R1W3.
//!
//! For each (latency, app): server throughput with monitors on/off per
//! model (→ overhead) and app throughput of eventual+monitors vs each
//! sequential model without monitors (→ benefit). Paper: overheads mostly
//! <4% (max 8%), benefits 23–80% growing with latency.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench table4_local_lab` for paper scale.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::{local_lab, LocalLabApp};
use optikv::metrics::report::{bench_scale, bench_seed, benefit_pct, overhead_pct};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.05);
    let seed = bench_seed();
    println!("# Table IV — local-lab overhead & benefit (scale {scale})\n");

    let apps = [
        (LocalLabApp::Conjunctive, "Conjunctive"),
        (LocalLabApp::Weather, "Weather"),
        (LocalLabApp::SocialMedia, "SocialMedia"),
    ];
    let mut t = Table::new(&[
        "lat(ms)", "application",
        "N3R1W1 srv", "ovh",
        "N3R2W2 srv", "ovh", "app", "benefit",
        "N3R1W3 srv", "ovh", "app", "benefit",
    ]);
    let mut benefits_by_latency: Vec<(f64, f64)> = Vec::new();
    for &lat in &[50.0, 100.0] {
        for &(app, label) in &apps {
            let r1w1_on = run(&local_lab(app, ConsistencyCfg::n3r1w1(), true, lat, scale, seed));
            let r1w1_off = run(&local_lab(app, ConsistencyCfg::n3r1w1(), false, lat, scale, seed));
            let r2w2_on = run(&local_lab(app, ConsistencyCfg::n3r2w2(), true, lat, scale, seed));
            let r2w2_off = run(&local_lab(app, ConsistencyCfg::n3r2w2(), false, lat, scale, seed));
            let r1w3_on = run(&local_lab(app, ConsistencyCfg::n3r1w3(), true, lat, scale, seed));
            let r1w3_off = run(&local_lab(app, ConsistencyCfg::n3r1w3(), false, lat, scale, seed));
            let b22 = benefit_pct(r1w1_on.app_tps, r2w2_off.app_tps);
            let b13 = benefit_pct(r1w1_on.app_tps, r1w3_off.app_tps);
            if app == LocalLabApp::SocialMedia {
                benefits_by_latency.push((lat, b13));
            }
            t.row(&[
                format!("{lat:.0}"),
                label.into(),
                format!("{:.0}", r1w1_on.server_tps),
                format!("{:.1}%", overhead_pct(r1w1_on.server_tps, r1w1_off.server_tps)),
                format!("{:.0}", r2w2_on.server_tps),
                format!("{:.1}%", overhead_pct(r2w2_on.server_tps, r2w2_off.server_tps)),
                format!("{:.0}", r2w2_off.app_tps),
                format!("+{b22:.0}%"),
                format!("{:.0}", r1w3_on.server_tps),
                format!("{:.1}%", overhead_pct(r1w3_on.server_tps, r1w3_off.server_tps)),
                format!("{:.0}", r1w3_off.app_tps),
                format!("+{b13:.0}%"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("# paper row (50 ms, Weather): ovh 0.2/7.1/3.2%, benefit +27.2% (R2W2) +45.0% (R1W3)");
    println!("# paper row (100 ms, Social): benefit +80% (R2W2) +60.7% (R1W3)");
    // shape check: benefit grows with latency (SocialMedia vs R1W3: 47% → 61%)
    if benefits_by_latency.len() == 2 {
        let (l1, b1) = benefits_by_latency[0];
        let (l2, b2) = benefits_by_latency[1];
        println!("# benefit growth with latency: {b1:.0}% @ {l1:.0} ms → {b2:.0}% @ {l2:.0} ms");
        assert!(b2 > b1 * 0.8, "benefit should not collapse as latency rises");
    }
    println!("# PASS");
}
