//! Recovery-strategy matrix — the cost of surviving violations three
//! ways, across the three consistency modes.
//!
//! Every cell runs the crash-churn conjunctive workload (two
//! crash/restart cycles, so each strategy must also terminate through a
//! dead server) and reports the three per-cell metrics:
//! violations/kop, mean time-to-recover, and the net application
//! throughput the strategy leaves behind. The strategies:
//!
//! * `full`  — stop-the-world freeze, window-log/snapshot restore, resume
//! * `reset` — checkpoint-free rolling reset: one server at a time drops
//!   its state and re-derives it from preference-list peers (no freeze)
//! * `stab`  — no rollback at all: violations are recorded and the
//!   application converges on its own
//!
//! A second section runs the `stab` strategy's demonstration workload:
//! the self-stabilizing coloring pass, which must keep completing tasks
//! with zero aborts through a crash/restart cycle.
//!
//! `BENCH_SCALE=1.0 cargo bench --bench recovery_matrix` for long runs.

use optikv::exp::runner::run;
use optikv::exp::scenarios::{
    recovery_matrix_cell, stabilize_coloring, RecoveryMode, RECOVERY_STRATEGIES,
};
use optikv::metrics::report::{bench_scale, bench_seed};
use optikv::util::stats::Table;

fn main() {
    let scale = bench_scale(0.1);
    let seed = bench_seed();
    println!(
        "# recovery-strategy matrix — mode x strategy under crash churn (scale {scale})\n"
    );

    let mut t = Table::new(&[
        "cell",
        "app ops/s",
        "viol/kop",
        "recoveries",
        "completed",
        "aborted",
        "deadline hits",
        "recover ms",
        "resets",
        "re-syncs",
    ]);
    for mode in RecoveryMode::ALL {
        for (strategy, _) in RECOVERY_STRATEGIES {
            let res = run(&recovery_matrix_cell(mode, strategy, scale, seed));
            t.row(&[
                res.name.clone(),
                format!("{:.0}", res.app_tps),
                format!("{:.2}", res.violations_per_kop),
                res.recoveries.to_string(),
                res.completed_recoveries.to_string(),
                res.recovery_aborts.to_string(),
                res.recovery_ack_timeouts.to_string(),
                format!("{:.1}", res.mean_recovery_ms),
                res.resets.to_string(),
                res.resyncs.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "full = freeze/restore/resume; reset = rolling peer re-derivation, no freeze; \
         stab = record only, app self-stabilizes"
    );

    println!("\n# stabilize demonstration — self-stabilizing coloring through a crash\n");
    let res = run(&stabilize_coloring(scale, seed));
    let (done, aborted) = {
        let m = res.metrics.borrow();
        (m.tasks_completed, m.tasks_aborted)
    };
    println!(
        "{}: app {:.1} ops/s | violations {} | tasks done {} | tasks aborted {} | \
         client restarts {} | crashes {}",
        res.name, res.app_tps, res.violations_detected, done, aborted, res.restarts, res.crashes
    );
}
