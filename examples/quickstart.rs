//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! 1. Parse a predicate from the paper's XML format (Fig. 3).
//! 2. Assemble a tiny optimistic-execution deployment (3 servers +
//!    clients on eventual consistency, monitors on).
//! 3. Run it and print what the monitors saw.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::run;
use optikv::metrics::report;
use optikv::predicate::spec::{PredId, PredicateSpec};
use optikv::sim::SEC;
use optikv::store::value::Interner;

fn main() {
    // --- 1. predicates are plain XML (Fig. 3 of the paper) ---------------
    let xml = r#"
<predicate>
 <type>semilinear</type>
 <conjClause>
  <id>0</id>
  <var> <name>x1</name> <value>1</value> </var>
  <var> <name>y1</name> <value>1</value> </var>
 </conjClause>
 <conjClause>
  <id>1</id>
  <var> <name>z2</name> <value>1</value> </var>
 </conjClause>
</predicate>"#;
    let interner = Interner::new();
    let spec = PredicateSpec::from_xml(PredId(0), "fig3-demo", xml, &mut interner.borrow_mut())
        .expect("parse");
    println!("parsed predicate `{}`: {} clause(s), kind {:?}", spec.name, spec.clauses.len(), spec.kind);
    println!("{}", spec.to_xml(&interner.borrow()));

    // --- 2. a small deployment: eventual consistency + monitors ----------
    let mut cfg = ExpConfig::new(
        "quickstart",
        ConsistencyCfg::n3r1w1(), // eventual (Table II)
        AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.1, put_pct: 0.5 },
    );
    cfg.n_clients = 6;
    cfg.duration = 30 * SEC;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };

    // --- 3. run and inspect ----------------------------------------------
    let res = run(&cfg);
    println!("\n{}", report::summarize(&res));
    println!(
        "monitors: {} candidates, {} pair verdicts, peak {} active predicates",
        res.candidates_seen, res.pairs_checked, res.active_preds_peak
    );
    if res.violations_detected > 0 {
        println!(
            "violations detected: {} (first latencies: {:?} ms)",
            res.violations_detected,
            &res.detection_latencies_ms[..res.detection_latencies_ms.len().min(5)]
        );
    }
    println!("\nquickstart OK");
}
