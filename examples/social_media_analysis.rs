//! End-to-end validation driver (DESIGN.md §4): the paper's headline
//! experiment. Distributed graph coloring of a power-law social graph on
//! the 3-region AWS topology, comparing:
//!
//!   * eventual consistency (N3R1W1) WITH the monitoring module, vs
//!   * sequential consistency (N3R1W3, N3R2W2) without it,
//!
//! and reporting throughput benefit (paper: +57% / +78%), violation
//! rarity (paper: ~1 per 4 500 s), detection latency (paper: ~2.2 s on
//! the global network) and task-time statistics (§VI-B).
//!
//! ```bash
//! cargo run --release --example social_media_analysis -- --scale 0.02
//! # full paper scale (50k nodes, long runs):
//! cargo run --release --example social_media_analysis -- --scale 1.0
//! ```

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::social_media_aws;
use optikv::metrics::report::{self, benefit_pct};
use optikv::util::cli::Args;
use optikv::util::stats::{self, Table};

fn main() {
    let args = Args::from_env();
    let scale = args.get_f64("scale", 0.01);
    let seed = args.get_u64("seed", 42);
    println!("== Social Media Analysis (graph coloring) — scale {scale} ==\n");

    let ev = run(&social_media_aws(ConsistencyCfg::n3r1w1(), true, scale, seed));
    println!("{}", report::summarize(&ev));
    let seq_r1w3 = run(&social_media_aws(ConsistencyCfg::n3r1w3(), false, scale, seed));
    println!("{}", report::summarize(&seq_r1w3));
    let seq_r2w2 = run(&social_media_aws(ConsistencyCfg::n3r2w2(), false, scale, seed));
    println!("{}", report::summarize(&seq_r2w2));

    let mut t = Table::new(&["Configuration", "App ops/s", "Benefit of N3R1W1+mon"]);
    t.row(&["N3R1W1 + monitors (eventual)".into(), format!("{:.1}", ev.app_tps), "—".into()]);
    t.row(&[
        "N3R1W3 (sequential)".into(),
        format!("{:.1}", seq_r1w3.app_tps),
        format!("+{:.0}% (paper: +57%)", benefit_pct(ev.app_tps, seq_r1w3.app_tps)),
    ]);
    t.row(&[
        "N3R2W2 (sequential)".into(),
        format!("{:.1}", seq_r2w2.app_tps),
        format!("+{:.0}% (paper: +78%)", benefit_pct(ev.app_tps, seq_r2w2.app_tps)),
    ]);
    println!("\n{}", t.render());

    // violation rarity + detection latency (paper §VI-B)
    let dur_s = ev.metrics.borrow().app_series().len() as f64;
    println!(
        "violations under eventual+monitor: {} detected / {} actual CS overlaps over ~{:.0}s",
        ev.violations_detected, ev.actual_me_violations, dur_s
    );
    if ev.violations_detected > 0 {
        println!(
            "  mean detection latency {:.0} ms, max {:.0} ms (paper: ~2 238 ms on the global network)",
            stats::mean(&ev.detection_latencies_ms),
            stats::max(&ev.detection_latencies_ms)
        );
    } else {
        println!("  (none this run — the paper saw ~1 per 4 500 s)");
    }

    // task-time statistics (paper: min 22 645 / avg 45 136 / max 217 369 ms at full scale)
    let m = ev.metrics.borrow();
    if !m.task_durations.is_empty() {
        let ds: Vec<f64> = m.task_durations.iter().map(|&d| d as f64 / 1e6).collect();
        println!(
            "tasks: {} completed, {} aborted; duration min {:.0} / avg {:.0} / max {:.0} ms",
            m.tasks_completed,
            m.tasks_aborted,
            stats::min(&ds),
            stats::mean(&ds),
            stats::max(&ds)
        );
    }
    println!(
        "peak active predicates: {} (inferred on demand from lock variable names)",
        ev.active_preds_peak
    );
}
