//! Weather Monitoring scenario (Fig. 12): planar-grid stencil workload
//! with a tunable GET/PUT mix on a single-region, 5-AZ deployment with
//! N = 5 replicas. Reports the benefit of eventual consistency with
//! monitoring over the two sequential configurations, and the monitoring
//! overhead, at PUT% = 25 and 50.
//!
//! ```bash
//! cargo run --release --example weather_monitoring -- --scale 0.1
//! ```

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::weather_regional;
use optikv::metrics::report::{benefit_pct, overhead_pct};
use optikv::util::cli::Args;
use optikv::util::stats::Table;

fn main() {
    let args = Args::from_env();
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    println!("== Weather Monitoring (planar grid, N=5, 10 clients) — scale {scale} ==\n");

    let mut t = Table::new(&[
        "PUT%",
        "N5R1W1+mon app/s",
        "N5R1W5 app/s",
        "benefit",
        "N5R3W3 app/s",
        "benefit",
        "mon overhead (server)",
    ]);
    for put_pct in [0.25, 0.5] {
        let ev = run(&weather_regional(ConsistencyCfg::n5r1w1(), true, put_pct, scale, seed));
        let s15 = run(&weather_regional(ConsistencyCfg::n5r1w5(), false, put_pct, scale, seed));
        let s33 = run(&weather_regional(ConsistencyCfg::n5r3w3(), false, put_pct, scale, seed));
        // overhead: same eventual config with monitors off
        let ev_off = run(&weather_regional(ConsistencyCfg::n5r1w1(), false, put_pct, scale, seed));
        t.row(&[
            format!("{:.0}%", put_pct * 100.0),
            format!("{:.1}", ev.app_tps),
            format!("{:.1}", s15.app_tps),
            format!("+{:.0}%", benefit_pct(ev.app_tps, s15.app_tps)),
            format!("{:.1}", s33.app_tps),
            format!("+{:.0}%", benefit_pct(ev.app_tps, s33.app_tps)),
            format!("{:.1}%", overhead_pct(ev.server_tps, ev_off.server_tps)),
        ]);
    }
    println!("{}", t.render());
    println!("paper (Fig. 12): benefit over N5R1W5 grows 18% → 37% as PUT% goes 25% → 50%;");
    println!("overhead ≤ 4%; balanced R/W (N5R3W3) beats write-heavy quorums as PUT% rises.");
}
