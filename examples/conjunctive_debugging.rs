//! Conjunctive distributed-debugging scenario (Table III): monitors
//! detect `¬P = P_1 ∧ … ∧ P_10` where each local predicate flips true
//! with probability β = 1%. Prints the detection-latency distribution in
//! the paper's Table III buckets plus the overhead/benefit numbers the
//! paper quotes for this workload (§VI-B).
//!
//! ```bash
//! cargo run --release --example conjunctive_debugging -- --scale 0.1
//! ```

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::runner::run;
use optikv::exp::scenarios::conjunctive_regional;
use optikv::metrics::report::{self, benefit_pct, overhead_pct};
use optikv::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    println!("== Conjunctive predicates (β = 1%, 10 conjuncts, N = 5) — scale {scale} ==\n");

    let ev = run(&conjunctive_regional(ConsistencyCfg::n5r1w1(), true, scale, seed));
    println!("{}", report::summarize(&ev));
    println!("\nDetection latency distribution (paper Table III: 99.93% < 50 ms, avg 8 ms, max 17 s):\n");
    println!("{}", report::latency_table(&ev.detection_latencies_ms));

    // overhead on each consistency model (paper: 7.81% / 6.50% / 4.66%)
    for c in [ConsistencyCfg::n5r1w1(), ConsistencyCfg::n5r1w5(), ConsistencyCfg::n5r3w3()] {
        let on = run(&conjunctive_regional(c, true, scale, seed));
        let off = run(&conjunctive_regional(c, false, scale, seed));
        println!(
            "overhead on {}: {:.2}% (server {:.0} vs {:.0} ops/s)",
            c.label(),
            overhead_pct(on.server_tps, off.server_tps),
            on.server_tps,
            off.server_tps
        );
    }

    // benefit of eventual (paper: +27.9% over N5R1W5, +20.2% over N5R3W3)
    let s15 = run(&conjunctive_regional(ConsistencyCfg::n5r1w5(), false, scale, seed));
    let s33 = run(&conjunctive_regional(ConsistencyCfg::n5r3w3(), false, scale, seed));
    println!(
        "\nbenefit of N5R1W1+mon: +{:.1}% over N5R1W5 (paper +27.9%), +{:.1}% over N5R3W3 (paper +20.2%)",
        benefit_pct(ev.app_tps, s15.app_tps),
        benefit_pct(ev.app_tps, s33.app_tps)
    );
}
